"""Checkpointing: atomic, asynchronous, elastic.

  atomic   : writes go to ``<dir>/tmp.<step>`` then a single os.replace —
             a crashed save can never corrupt the latest checkpoint.
  async    : a background thread does serialization + IO; the train loop
             only blocks if a previous save is still in flight (one-deep
             pipeline, bounded memory). `wait()` drains before exit.
  elastic  : restore() takes an optional target sharding tree; leaves are
             device_put to the *new* mesh layout, so a 256-chip checkpoint
             restores onto 512 chips (or 8) — node-failure recovery with a
             different pod count is a first-class path.

Format: one ``.npz`` with flattened key paths + a JSON sidecar (step,
metadata, tree structure). bfloat16 leaves are bit-cast to uint16 for
numpy compatibility and restored exactly.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

_BF16 = "bfloat16"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def _leafkey_order(tree):
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save_params(ckpt_dir: str, step: int, params: Params,
                metadata: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(params)
    dtypes = {}
    store = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            store[k] = v.view(np.uint16)
            dtypes[k] = _BF16
        else:
            store[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(os.path.join(tmp, "arrays.npz"), **store)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "dtypes": dtypes,
                   "metadata": metadata or {},
                   "time": time.time()}, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def read_meta(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Load a checkpoint's meta.json (step, metadata, dtypes) without
    touching the arrays — lets callers decide the restore template (e.g.
    params-only vs {'params','state'} engine bundles) before restoring."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")
    with open(path) as f:
        return json.load(f)


def restore_params(ckpt_dir: str, like: Params, step: Optional[int] = None,
                   shardings=None) -> tuple[Params, dict]:
    """Restore into the structure of ``like``. ``shardings`` (optional tree
    or single sharding) re-lays leaves onto the current mesh (elastic)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys = _leafkey_order(like)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None and not isinstance(
                        shardings, jax.sharding.Sharding)
                    else [shardings] * len(keys))
    out = []
    for i, (k, proto) in enumerate(zip(keys, leaves_like)):
        arr = data[k]
        if meta["dtypes"][k] == _BF16:
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == tuple(proto.shape), (
            f"{k}: ckpt shape {arr.shape} != model shape {proto.shape}")
        sh = shard_leaves[i]
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta


class Checkpointer:
    """Async, keep-last-k checkpoint manager."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, params: Params,
             metadata: Optional[dict] = None, block: bool = False) -> None:
        self.wait()                       # one-deep pipeline
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)

        def work():
            try:
                save_params(self.dir, step, host, metadata)
                self._gc()
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore(self, like: Params, step: Optional[int] = None,
                shardings=None):
        self.wait()
        return restore_params(self.dir, like, step, shardings)
