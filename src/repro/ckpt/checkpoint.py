"""Checkpointing: atomic, asynchronous, elastic.

  atomic   : writes go to ``<dir>/tmp.<step>`` then a single os.replace —
             a crashed save can never corrupt the latest checkpoint.
  async    : a background thread does serialization + IO; the train loop
             only blocks if a previous save is still in flight (one-deep
             pipeline, bounded memory). `wait()` drains before exit.
  elastic  : restore() takes an optional target sharding tree; leaves are
             device_put to the *new* mesh layout, so a 256-chip checkpoint
             restores onto 512 chips (or 8) — node-failure recovery with a
             different pod count is a first-class path.

Format: one ``.npz`` with flattened key paths + a JSON sidecar (step,
metadata, tree structure). bfloat16 leaves are bit-cast to uint16 for
numpy compatibility and restored exactly.

Crash safety is two layers deep. The tmp+``os.replace`` rename means a
save killed mid-write never *replaces* a good checkpoint — but the
directory that was being renamed-to could still be damaged by the
filesystem itself (torn page, truncated npz, bit rot). So every save also
records a CRC-32 over the stored array bytes in ``meta.json``
("checksum"); ``verify_checkpoint`` recomputes it, ``latest_good_step``
walks the step directories newest-first to the most recent checkpoint
that verifies, and restores with ``step=None`` resolve through it — a
resumed run silently falls back to the last good chunk boundary instead
of crashing (or worse, training on garbage). An explicitly requested
step that fails verification raises ``CorruptCheckpointError``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zipfile
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

_BF16 = "bfloat16"


class CorruptCheckpointError(RuntimeError):
    """An explicitly requested checkpoint failed its content checksum."""


def _content_checksum(store: Dict[str, np.ndarray]) -> int:
    """CRC-32 over the stored (post-bitcast) arrays in sorted key order —
    key names and shapes included, so a renamed or reshaped leaf is as
    detectable as flipped payload bytes."""
    crc = 0
    for k in sorted(store):
        a = np.ascontiguousarray(store[k])
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(repr((a.shape, str(a.dtype))).encode(), crc)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def _leafkey_order(tree):
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save_params(ckpt_dir: str, step: int, params: Params,
                metadata: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(params)
    dtypes = {}
    store = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            store[k] = v.view(np.uint16)
            dtypes[k] = _BF16
        else:
            store[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(os.path.join(tmp, "arrays.npz"), **store)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "dtypes": dtypes,
                   "metadata": metadata or {},
                   "checksum": _content_checksum(store),
                   "time": time.time()}, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def verify_checkpoint(ckpt_dir: str, step: int) -> bool:
    """True iff step's checkpoint is readable and its stored bytes match
    the checksum recorded at save time. Checkpoints predating checksums
    verify as good when readable: os.replace already guarantees they are
    complete, there is just nothing to compare their bytes against."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            store = {k: data[k] for k in data.files}
        if "checksum" not in meta:
            return True
        return _content_checksum(store) == int(meta["checksum"])
    except (OSError, ValueError, KeyError, zlib.error,
            zipfile.BadZipFile):
        return False


def latest_good_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose checkpoint verifies — the fallback walk a resume
    takes past a corrupted latest checkpoint to the last good chunk
    boundary."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_")), reverse=True)
    for s in steps:
        if verify_checkpoint(ckpt_dir, s):
            return s
    return None


def read_meta(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Load a checkpoint's meta.json (step, metadata, dtypes) without
    touching the arrays — lets callers decide the restore template (e.g.
    params-only vs {'params','state'} engine bundles) before restoring.
    step=None resolves to the latest checkpoint that passes verification
    (falling back past corrupted saves)."""
    if step is None:
        step = latest_good_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints under "
                                    f"{ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")
    with open(path) as f:
        return json.load(f)


def restore_params(ckpt_dir: str, like: Params, step: Optional[int] = None,
                   shardings=None) -> tuple[Params, dict]:
    """Restore into the structure of ``like``. ``shardings`` (optional tree
    or single sharding) re-lays leaves onto the current mesh (elastic)."""
    if step is None:
        step = latest_good_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints under "
                                    f"{ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            data = {k: npz[k] for k in npz.files}
    except FileNotFoundError:
        raise
    except (OSError, ValueError, zlib.error, zipfile.BadZipFile) as e:
        raise CorruptCheckpointError(
            f"checkpoint step {step} under {ckpt_dir} is unreadable "
            f"({e}). Restore with step=None to fall back to the latest "
            f"good checkpoint.") from e
    if "checksum" in meta and \
            _content_checksum(data) != int(meta["checksum"]):
        raise CorruptCheckpointError(
            f"checkpoint step {step} under {ckpt_dir} fails its content "
            f"checksum — bytes on disk do not match what was saved. "
            f"Restore with step=None to fall back to the latest good "
            f"checkpoint.")
    keys = _leafkey_order(like)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None and not isinstance(
                        shardings, jax.sharding.Sharding)
                    else [shardings] * len(keys))
    out = []
    for i, (k, proto) in enumerate(zip(keys, leaves_like)):
        arr = data[k]
        if meta["dtypes"][k] == _BF16:
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == tuple(proto.shape), (
            f"{k}: ckpt shape {arr.shape} != model shape {proto.shape}")
        sh = shard_leaves[i]
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta


class Checkpointer:
    """Async, keep-last-k checkpoint manager."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, params: Params,
             metadata: Optional[dict] = None, block: bool = False) -> None:
        self.wait()                       # one-deep pipeline
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)

        def work():
            try:
                save_params(self.dir, step, host, metadata)
                self._gc()
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore(self, like: Params, step: Optional[int] = None,
                shardings=None):
        self.wait()
        return restore_params(self.dir, like, step, shardings)
