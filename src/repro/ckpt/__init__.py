from repro.ckpt.checkpoint import (Checkpointer, latest_step, read_meta,
                                   restore_params, save_params)
