from repro.ckpt.checkpoint import (Checkpointer, latest_step, restore_params,
                                   save_params)
