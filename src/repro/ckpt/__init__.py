from repro.ckpt.checkpoint import (Checkpointer, CorruptCheckpointError,
                                   latest_good_step, latest_step, read_meta,
                                   restore_params, save_params,
                                   verify_checkpoint)
