"""Memory-aware layout planner.

Decides, per (arch × shape × mesh):
  * fsdp axis      — None | 'data' | ('pod','data'): weight sharding beyond TP
  * client_mode    — 'parallel' (vmap M over 'data') vs 'sequential'
                     (scan over clients; one FSDP'd working copy)
  * aggregation    — 'dense' vs 'seed_replay'

Heuristic: v5e has 16 GiB HBM/chip. TP-only per-chip weight bytes
2·P/16; if that exceeds PARALLEL_BUDGET the per-client replicas of
client-parallel mode can't fit and we go sequential + FSDP. The dry-run's
memory_analysis() is the ground truth that validates the plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.models import split_dims

HBM_PER_CHIP = 16 * 2 ** 30          # v5e
PARALLEL_BUDGET = 6 * 2 ** 30        # TP-shard of server params + working set
FSDP_BUDGET = 10 * 2 ** 30


@dataclasses.dataclass(frozen=True)
class Plan:
    fsdp: Optional[Tuple[str, ...]]   # axis name tuple or None
    client_mode: str                  # parallel | sequential
    aggregation: str                  # dense | seed_replay
    tp_bytes_per_chip: int            # estimate backing the decision
    replay: str = "auto"              # auto | fused | scan (record apply)

    @property
    def fsdp_axes(self):
        if self.fsdp is None:
            return None
        return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]


def model_bytes(cfg: ModelConfig) -> int:
    d_c, d_s = split_dims(cfg, cfg.default_cut_units)
    return 2 * (d_c + d_s)            # bf16


@dataclasses.dataclass(frozen=True)
class EventStorePlan:
    """Placement of fleet-scaling semi-async state on the mesh.

    slot_axis   mesh axis for the record store's leading slot dim (the
                arrival-slot ring under timeline='sparse', client id under
                'dense'); None replicates.
    client_axis mesh axis for the population's (M,) client vectors.
    Both default to 'data' — the ring and the fleet live where the batch
    does — and fall back to replication when the dim doesn't divide the
    axis (pjit rejects uneven shardings). ``bytes_per_device`` is the
    store estimate backing the decision.
    """
    slot_axis: Optional[str]
    client_axis: Optional[str]
    capacity: int
    n_clients: int
    bytes_per_device: int


def store_bytes(capacity: int, tau: int, n_pert: int) -> int:
    """Record-store footprint: (cap, τ, P, 2) u32 keys + (cap, τ, P) f32
    coeffs + the (cap,) client key/coeff/loss columns."""
    return capacity * (tau * n_pert * 12 + 16)


def plan_event_store(capacity: int, n_clients: int, mesh: MeshConfig,
                     *, tau: int = 1, n_pert: int = 1) -> EventStorePlan:
    """Decide 'data'-axis sharding for the ring store + population vectors.

    The slot dim shards over 'data' when it divides the axis size (the
    sparse step's gather/scatter over slot indices stays a GSPMD-lowered
    collective either way — the spec is a layout hint, never a semantics
    change), and likewise the client dim of the cohort vectors.
    """
    sizes = dict(zip(mesh.axes, mesh.shape))
    data = sizes.get("data", 1)
    slot_axis = "data" if data > 1 and capacity % data == 0 else None
    client_axis = "data" if data > 1 and n_clients % data == 0 else None
    per_dev = store_bytes(capacity, tau, n_pert) // (
        data if slot_axis else 1)
    return EventStorePlan(slot_axis=slot_axis, client_axis=client_axis,
                          capacity=capacity, n_clients=n_clients,
                          bytes_per_device=per_dev)


def plan_for(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
             aggregation: str = "dense", replay: str = "auto") -> Plan:
    tp = mesh.shape[-1]
    tp_bytes = model_bytes(cfg) // tp
    multi_pod = len(mesh.shape) == 3
    if shape.kind != "train":
        # serving: weights always fit TP-sharded except the giants -> FSDP
        fsdp = None if tp_bytes <= FSDP_BUDGET else (
            ("pod", "data") if multi_pod else ("data",))
        return Plan(fsdp, "parallel", aggregation, tp_bytes, replay)
    if tp_bytes <= PARALLEL_BUDGET:
        return Plan(None, "parallel", aggregation, tp_bytes, replay)
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return Plan(fsdp, "sequential", aggregation, tp_bytes, replay)
