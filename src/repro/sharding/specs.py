"""Parameter / batch / cache PartitionSpec rules.

Conventions (mesh axes: optional 'pod', then 'data', 'model'):
  'model' : tensor parallelism — attention head projections, FFN hidden,
            expert dim (EP) when divisible, vocab for embed/head.
  fsdp    : optional weight sharding over 'data' (or ('pod','data')) for
            archs whose TP-sharded weights exceed the per-chip budget.
  batch / client dims ride 'data' (+'pod').

Every rule is divisibility-guarded: a dim that doesn't divide the mesh axis
falls back to replication (pjit rejects uneven in_shardings). Specs are
*performance hints* — GSPMD keeps the math correct for any choice; the
roofline pass measures how good the hints are. Rules are name-based over
the param tree; stacked unit dims (leading n_units from the scan layout)
are never sharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# column-parallel: output features on 'model'
_COL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "w_up", "w_x",
        "wq_a", "wq_b", "wkv_a", "wkv_b", "lm_head", "image_proj",
        "audio_proj"}
# row-parallel: input features on 'model'
_ROW = {"wo", "out_proj", "w_down", "x_proj", "dt_proj"}
# feature-sharded vectors / matrices keyed on the d_inner/d_up dim
_FEAT0 = {"A_log", "D", "dt_bias", "conv_b"}
_MODEL_IN = {"w_i", "w_f"}

DEFAULT_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _leaf_name(path) -> str:
    return str(getattr(path[-1], "key", getattr(path[-1], "idx", path[-1])))


def _path_has(path, name: str) -> bool:
    return any(str(getattr(p, "key", "")) == name for p in path)


def _axsize(axis, sizes: Dict[str, int]) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _guard(dim: int, axis, sizes: Dict[str, int]):
    """axis if dim divides the axis size, else None (replicate)."""
    return axis if (axis is not None and dim % _axsize(axis, sizes) == 0) \
        else None


def param_pspecs(cfg: ModelConfig, shapes: Any, *, fsdp: Optional[Any] = None,
                 model_axis: str = "model",
                 axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    """PartitionSpec tree mirroring ``shapes`` (arrays or ShapeDtypeStructs).

    fsdp: None, 'data', or ('pod','data') — the weight-sharding axis.
    """
    M = model_axis
    sizes = axis_sizes or DEFAULT_AXIS_SIZES

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        stacked = _path_has(path, "units") or _path_has(path, "enc_units")
        lead = (None,) * (1 if stacked else 0)
        dims = shape[(1 if stacked else 0):]
        g = lambda i, ax: _guard(dims[i], ax, sizes)

        # --- MoE expert weights: (E, D, F) ---
        if name in ("wi", "wg", "wo") and len(dims) == 3:
            if dims[0] % _axsize(M, sizes) == 0:   # EP: experts over model
                return P(*lead, M, g(1, fsdp), None)
            if name == "wo":                       # TP inside experts
                return P(*lead, None, g(1, M), g(2, fsdp))
            return P(*lead, None, g(1, fsdp), g(2, M))
        if name == "router":
            return P(*lead, g(0, fsdp), None)
        if name == "embed" and not stacked:
            return P(_guard(shape[0], M, sizes), _guard(shape[1], fsdp, sizes))
        if name in _COL and len(dims) == 2:
            return P(*lead, g(0, fsdp), g(1, M))
        if name in _ROW and len(dims) == 2:
            return P(*lead, g(0, M), g(1, fsdp))
        if name in _MODEL_IN and len(dims) == 2:
            return P(*lead, g(0, M), None)
        if name == "conv_w" and len(dims) == 2:
            return P(*lead, None, g(1, M))
        if name in _FEAT0 and len(dims) >= 1:
            return P(*lead, g(0, M), *((None,) * (len(dims) - 1)))
        # norms, biases, gates, sLSTM recurrent blocks: replicate
        return P(*lead, *((None,) * len(dims)))

    return jax.tree_util.tree_map_with_path(rule, shapes)


def population_pspecs(vectors: Dict[str, Any], *, client_axis="data",
                      axis_sizes: Optional[Dict[str, int]] = None
                      ) -> Dict[str, P]:
    """Specs for ClientPopulation.client_vectors(): every (M,) fleet
    vector shards its client dim over ``client_axis`` (divisibility-
    guarded — uneven fleets replicate). Trailing dims, if a caller stacks
    per-client features, replicate."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    return {name: P(_guard(np.shape(v)[0], client_axis, sizes),
                    *((None,) * (np.ndim(v) - 1)))
            for name, v in vectors.items()}


def event_store_pspecs(store: Dict[str, Any], *, slot_axis="data",
                       axis_sizes: Optional[Dict[str, int]] = None
                       ) -> Dict[str, P]:
    """Specs for the semi-async record store (events.init_store): the
    leading slot dim — client id in the dense layout, arrival slot in the
    ring layout — shards over ``slot_axis``; the record axes (τ, P, key
    words) replicate. The sparse step's scatter/gather over slot indices
    lowers to GSPMD collectives against this layout, so the in-flight
    buffer scales with the fleet instead of one device's memory."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    return {name: P(_guard(np.shape(v)[0], slot_axis, sizes),
                    *((None,) * (np.ndim(v) - 1)))
            for name, v in store.items()}


def batch_pspec(kind: str, multi_pod: bool, *, stacked_clients: bool) -> P:
    """Spec for token-like batch leaves.

    train (stacked M, b, S): M -> 'data', per-client batch b -> 'pod'.
    serve (B, S) / (B, 1):   B -> ('pod','data') | 'data'.
    """
    if stacked_clients:
        return P("data", "pod" if multi_pod else None, None)
    return P(("pod", "data") if multi_pod else "data", None)


def ctx_pspec(multi_pod: bool, *, stacked_clients: bool) -> P:
    """image_embeds / frames: (…, T, D) with batch dims as batch_pspec."""
    if stacked_clients:
        return P("data", "pod" if multi_pod else None, None, None)
    return P(("pod", "data") if multi_pod else "data", None, None)


def cache_pspecs(cfg: ModelConfig, cache_shapes: Any, batch: int,
                 multi_pod: bool, model_axis: str = "model",
                 axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    """Decode-cache specs. Layout decisions:

    * KV/latent caches: batch over 'data' when it divides; the cache
      sequence dim over 'model' (flash-decoding: the softmax reductions over
      the sharded seq dim lower to small all-reduces). For global_batch=1
      (long_500k) the seq dim takes BOTH ('data','model') (+'pod').
    * SSM/recurrent states: feature dims over 'model', batch over 'data'.
    """
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    data_ax = ("pod", "data") if multi_pod else "data"
    batch_ok = batch % _axsize(data_ax, sizes) == 0
    if not batch_ok and batch % sizes.get("data", 16) == 0:
        data_ax, batch_ok = "data", True

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape               # (n_units, B, ...)
        b_ax = data_ax if batch_ok else None
        wide_seq = (model_axis if batch_ok else
                    (("pod", "data", "model") if multi_pod
                     else ("data", "model")))
        if name in ("k", "v") and len(shape) == 5:     # (u,B,H,S,dh)
            return P(None, b_ax, None, _guard(shape[3], wide_seq, sizes),
                     None)
        if name in ("c_kv", "k_rope") and len(shape) == 4:  # (u,B,S,r)
            return P(None, b_ax, _guard(shape[2], wide_seq, sizes), None)
        if name == "h" and len(shape) == 4:            # mamba (u,B,d_in,N)
            return P(None, b_ax, _guard(shape[2], model_axis, sizes), None)
        if name == "conv" and len(shape) == 4:         # (u,B,d_conv-1,d_in)
            return P(None, b_ax, None, _guard(shape[3], model_axis, sizes))
        if name == "C" and len(shape) == 5:            # mlstm (u,B,H,d,d)
            return P(None, b_ax, None, None, None)
        if name in ("n", "m", "c") and len(shape) >= 3:
            return P(None, b_ax, *((None,) * (len(shape) - 2)))
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
