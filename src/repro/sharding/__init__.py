from repro.sharding.specs import batch_pspec, param_pspecs, cache_pspecs
from repro.sharding.planner import Plan, plan_for
