"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6
experts (d_expert=1536). [arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    attn_impl="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    d_head=192,                  # qk_nope + qk_rope
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_expert=1536),
    max_seq_len=131_072,
    sub_quadratic=False,         # MLA is still O(S^2) -> long_500k skipped
    default_cut_units=2,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=256, kv_lora_rank=16, q_lora_rank=24, qk_rope_dim=8,
    qk_nope_dim=16, v_head_dim=16, d_head=24,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32),
    max_seq_len=256,
)
