"""xlstm-350m [ssm] — mLSTM (matrix memory) + sLSTM (scalar memory) blocks,
no separate FFN (d_ff=0; blocks are self-contained). [arXiv:2405.04517;
unverified]"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    xlstm=XLSTMConfig(n_heads=4, chunk=64),
    max_seq_len=524_288,
    sub_quadratic=True,          # recurrent -> long_500k eligible
    default_cut_units=1,
)

SMOKE = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
    vocab_size=256, xlstm=XLSTMConfig(n_heads=4, chunk=16),
    max_seq_len=256,
)
