"""internlm2-1.8b [dense] — GQA. [arXiv:2403.17297; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    max_seq_len=32_768,
    sub_quadratic=False,
    default_cut_units=2,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=256,
)
