"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE every
other layer (16 experts, top-2). [arXiv:2403.19887; hf]"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2),
    moe_every=2,
    moe_offset=1,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    max_seq_len=524_288,
    sub_quadratic=True,          # 1:7 SSM hybrid -> long_500k eligible
    default_cut_units=1,
)

SMOKE = CONFIG.replace(
    n_layers=16, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, moe=MoEConfig(n_experts=4, top_k=2),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=16),
    max_seq_len=256,
)
