"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    max_seq_len=524_288,
    sub_quadratic=True,          # SWA -> O(S*w) -> long_500k eligible
    default_cut_units=2,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, sliding_window=32, moe=MoEConfig(n_experts=4, top_k=2),
    max_seq_len=256,
)
