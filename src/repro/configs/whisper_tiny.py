"""whisper-tiny [audio] — enc-dec backbone; conv frontend is a stub
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                  # decoder blocks
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm_type="layernorm",
    mlp_type="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=4,
    n_audio_frames=1500,
    max_seq_len=32_768,          # backbone-only decode shape support
    sub_quadratic=False,
    default_cut_units=1,         # cut inside the encoder
)

SMOKE = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, n_audio_frames=16, max_seq_len=256,
)
