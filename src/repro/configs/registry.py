"""Architecture registry: ``--arch <id>`` lookup for launchers, the dry-run
and benchmarks. IDs are the assignment names."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, SHAPES_BY_NAME, ShapeConfig

_MODULES: Dict[str, str] = {
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "olmo-1b": "repro.configs.olmo_1b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "xlstm-350m": "repro.configs.xlstm_350m",
    # paper's own setting (not in the assigned pool)
    "paper-opt-1.3b": "repro.configs.paper_opt_1_3b",
}

ASSIGNED: List[str] = [k for k in _MODULES if not k.startswith("paper-")]


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def cells(include_skips: bool = False):
    """All assigned (arch, shape) cells. long_500k only for sub-quadratic
    archs; skipped cells yield (arch, shape, 'skip:<reason>') when requested."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                if include_skips:
                    yield arch, shape, "skip:full-attention is O(S^2) at 500k"
                continue
            yield (arch, shape, "run") if include_skips else (arch, shape)
