"""qwen3-14b [dense] — GQA with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    max_seq_len=131_072,
    sub_quadratic=False,
    default_cut_units=4,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=256,
)
