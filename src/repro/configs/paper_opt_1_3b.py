"""paper-opt-1.3b — the paper's own LLM setting (OPT-1.3B fine-tuned on
SST-2, §5): 24 transformer blocks, enabling the cut-layer × tau sweep of
Fig. 3 / Table 4. Not part of the assigned pool; used by examples and the
paper-reproduction benchmarks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-opt-1.3b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=50272,
    norm_type="layernorm",
    mlp_type="gelu",
    max_seq_len=2048,
    sub_quadratic=False,
    default_cut_units=2,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, max_seq_len=256,
)
