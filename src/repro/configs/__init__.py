from repro.configs.base import (MeshConfig, ModelConfig, MoEConfig, SFLConfig,
                                SHAPES, SHAPES_BY_NAME, ShapeConfig, TrainConfig)
from repro.configs.registry import ASSIGNED, cells, get_config
