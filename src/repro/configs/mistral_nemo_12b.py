"""mistral-nemo-12b [dense] — GQA, 128k context, head_dim=128 (decoupled
from d_model/n_heads). [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    sub_quadratic=False,
    default_cut_units=4,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab_size=256, max_seq_len=256,
)
