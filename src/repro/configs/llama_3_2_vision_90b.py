"""llama-3.2-vision-90b [vlm] — gated cross-attn image layers every 5th
block; vision frontend is a stub (input_specs provides precomputed patch
embeddings). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    n_image_tokens=1600,
    max_seq_len=131_072,
    sub_quadratic=False,
    default_cut_units=1,
)

SMOKE = CONFIG.replace(
    n_layers=10, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_image_tokens=8, max_seq_len=256,
)
