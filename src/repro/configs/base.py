"""Config system for the repro framework.

Everything is a frozen dataclass so configs are hashable (usable as jit
static args) and serializable. One file per assigned architecture lives in
this package; each exposes ``CONFIG`` (full-size) and ``SMOKE`` (reduced,
CPU-runnable) ``ModelConfig`` instances.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:                      # annotation-only: configs must not
    from repro.core.faults import FaultPlan              # import core
    from repro.core.population import ClientPopulation


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (GShard-style capacity dispatch)."""
    n_experts: int
    top_k: int
    n_shared: int = 0           # always-on shared experts (DeepSeek-V2)
    d_expert: int = 0           # expert FFN hidden size (0 -> use model d_ff)
    capacity_factor: float = 1.25
    group_size: int = 0         # dispatch group size in tokens (0 -> auto)


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)
    chunk: int = 64             # chunked selective-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    chunk: int = 64             # mLSTM chunkwise-parallel block length
    proj_factor: float = 2.0    # mLSTM up-projection factor
    slstm_proj_factor: float = 1.3334


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. ``block_pattern`` is the repeating unit of
    block types; ``n_layers`` must be a multiple of its length. Block types:
    ``attn`` | ``mamba`` | ``mlstm`` | ``slstm`` | ``xattn`` (cross-attn to
    image/encoder stream).
    """
    name: str
    family: str                 # dense|moe|hybrid|vlm|audio|ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 -> d_model // n_heads

    # --- attention ---
    attn_impl: str = "gqa"      # gqa|mla
    qk_norm: bool = False
    sliding_window: int = 0     # 0 -> full attention
    rope_theta: float = 10_000.0
    # MLA (DeepSeek-V2) dims
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- norms / mlp ---
    norm_type: str = "rmsnorm"  # rmsnorm|layernorm|nonparam_ln
    mlp_type: str = "swiglu"    # swiglu|gelu
    moe: Optional[MoEConfig] = None
    moe_every: int = 1          # layer i uses MoE iff i % moe_every == moe_offset
    moe_offset: int = 0

    # --- block pattern ---
    block_pattern: Tuple[str, ...] = ("attn",)
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # --- encoder/decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 0     # precomputed frame embeddings (stub frontend)

    # --- vlm (llama-3.2-vision) ---
    n_image_tokens: int = 0     # precomputed patch embeddings (stub frontend)

    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_seq_len: int = 131_072

    # --- paper (SFL) defaults for this arch ---
    default_cut_units: int = 1  # client-side depth in repeating units
    sub_quadratic: bool = False # eligible for long_500k decode

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern len {len(self.block_pattern)}")

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def unit_len(self) -> int:
        return len(self.block_pattern)

    def layer_uses_moe(self, pos_in_unit: int) -> bool:
        if self.moe is None:
            return False
        # pattern-static: unit_len must be a multiple of moe_every
        return pos_in_unit % self.moe_every == self.moe_offset

    def replace(self, **kw) -> "ModelConfig":
        # d_head is derived from d_model/n_heads in __post_init__; reset it
        # when its sources change unless explicitly overridden.
        if ("d_model" in kw or "n_heads" in kw) and "d_head" not in kw:
            kw["d_head"] = 0
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train|prefill|decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class SFLConfig:
    """MU-SplitFed algorithm config (the paper's technique).

    The client fleet is described by ``population`` (a
    ``repro.core.population.ClientPopulation`` — heterogeneous cohorts,
    Markov availability, per-tier comm scales). The scalar knobs
    ``straggler_rate`` / ``participation`` are the DEPRECATED
    single-homogeneous-cohort shorthand; both paths resolve through
    ``ClientPopulation.resolve(sfl)`` and the shorthand reproduces the
    historical schedules bit-for-bit.
    """
    n_clients: int = 16         # M
    tau: int = 2                # unbalanced server update steps per round
    n_perturbations: int = 1    # P (SPSA averaging)
    cut_units: int = 1          # L_c in repeating units
    lr_server: float = 1e-2     # eta_s
    lr_client: float = 5e-3     # eta_c
    lr_global: float = 0.3      # eta_g
    zo_eps: float = 5e-3        # lambda (smoothing)
    participation: float = 1.0  # DEPRECATED shorthand (see population)
    perturbation_dist: str = "gaussian"  # gaussian|sphere (paper: sphere)
    seed: int = 0
    # straggler simulation
    straggler_rate: float = 0.0     # DEPRECATED shorthand (see population)
    deadline: float = 0.0           # drop clients beyond deadline (0 = off)
    # semi-async execution (engine mode='async', core/events.py): commit a
    # server version once `quorum` contributions arrived (0 = wait for all
    # pending — the synchronous barrier); a contribution applied s commits
    # after its fetch weighs staleness_discount**s (1.0 = no discount)
    quorum: int = 0
    staleness_discount: float = 1.0
    # timeline backend for mode='async': 'dense' precompiles (V, M) rows
    # (the small-M reference); 'sparse' streams (V, k_max) commit batches
    # over an arrival-slot ring store of ring_capacity slots.  0 = auto for
    # both knobs (events.resolve_store_geometry); with the autos and
    # quorum=0 the sparse path is bit-equivalent to dense.
    timeline: str = "dense"
    k_max: int = 0
    ring_capacity: int = 0
    # the first-class fleet spec (hashable, jit-static like the rest of
    # this config); None -> single cohort from the scalar shorthands
    population: Optional["ClientPopulation"] = None
    # fault injection + graceful degradation (core/faults.py): None (or
    # FaultPlan.none()) keeps the event stream bit-exact with the clean
    # engine; quorum_timeout > 0 lets a commit proceed with however many
    # contributions arrived once t + quorum_timeout passes (weights
    # renormalized — the no-deadlock escape); lost deliveries retransmit
    # up to max_retries times before the contribution is dropped.
    faults: Optional["FaultPlan"] = None
    quorum_timeout: float = 0.0
    max_retries: int = 3


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    lr: float = 1e-3
    optimizer: str = "adam"     # for first-order baselines
    warmup: int = 10
    seed: int = 0
