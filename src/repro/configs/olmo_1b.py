"""olmo-1b [dense] — non-parametric LayerNorm, tied embeddings, MHA.
[arXiv:2402.00838; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparam_ln",
    tie_embeddings=True,
    max_seq_len=4096,
    sub_quadratic=False,
    default_cut_units=2,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, max_seq_len=256,
)
