"""Finding model, inline suppressions, baseline, and the analysis runner.

The contract (mirrors how the CI gate consumes this):

* every rule emits ``Finding`` records (file, line, rule id, severity,
  message);
* ``# lint: ignore[rule-id]`` on the flagged line (or alone on the line
  above) suppresses that rule there; bare ``# lint: ignore`` suppresses
  every rule on the line;
* ``analysis/baseline.json`` holds accepted pre-existing findings keyed on
  (rule, path, source-line text) — line *numbers* are not part of the key,
  so unrelated edits don't invalidate the baseline, but touching a
  baselined line re-surfaces its finding;
* the CLI exits non-zero only on findings that are neither suppressed nor
  baselined ("new" findings).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import astutil

_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore(?:\[([A-Za-z0-9_,\- ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # rule id, e.g. 'rng-discipline'
    path: str            # path as given to the runner (repo-relative in CI)
    line: int            # 1-indexed
    severity: str        # 'error' | 'warning'
    message: str
    code: str = ""       # stripped source line (the baseline key context)

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path.replace(os.sep, "/"), self.code)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.severity}: {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """Base rule: subclasses set ``id``/``doc`` and implement ``check``."""

    id: str = ""
    doc: str = ""

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "FileContext", node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        line = getattr(node, "lineno", 0)
        return Finding(rule=self.id, path=ctx.path, line=line,
                       severity=severity, message=message,
                       code=ctx.line_text(line))


class FileContext:
    """One parsed file handed to every rule: tree (with parents), source
    lines, and resolved import aliases."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = astutil.attach_parents(ast.parse(source, filename=path))
        self.aliases = astutil.collect_aliases(self.tree)
        self.consts = astutil.module_consts(self.tree)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def suppressed(self, finding: Finding) -> bool:
        """Inline ``# lint: ignore[...]`` on the line or alone above it."""
        for ln in (finding.line, finding.line - 1):
            if not 1 <= ln <= len(self.lines):
                continue
            text = self.lines[ln - 1]
            m = _IGNORE_RE.search(text)
            if not m:
                continue
            if ln == finding.line - 1 and not text.strip().startswith("#"):
                continue             # line above counts only if comment-only
            rules = m.group(1)
            if rules is None:
                return True
            if finding.rule in {r.strip() for r in rules.split(",")}:
                return True
        return False


def default_rules() -> List[Rule]:
    from repro.analysis.rules_faults import FaultIsolation
    from repro.analysis.rules_jit import (DonationSafety, HostSync,
                                          TraceLeak)
    from repro.analysis.rules_obs import TelemetryPurity
    from repro.analysis.rules_pallas import PallasBudget
    from repro.analysis.rules_rng import JaxKeyReuse, RngDiscipline
    return [RngDiscipline(), JaxKeyReuse(), TraceLeak(), HostSync(),
            DonationSafety(), PallasBudget(), TelemetryPurity(),
            FaultIsolation()]


def iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return out


def analyze_source(source: str, path: str = "<string>",
                   rules: Optional[Sequence[Rule]] = None,
                   *, keep_suppressed: bool = False) -> List[Finding]:
    """All findings for one source blob (inline suppressions applied)."""
    ctx = FileContext(path, source)
    found: List[Finding] = []
    for rule in (rules if rules is not None else default_rules()):
        for f in rule.check(ctx):
            if keep_suppressed or not ctx.suppressed(f):
                found.append(f)
    return sorted(found, key=lambda f: (f.path, f.line, f.rule))


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Findings across files/dirs. Unparseable files yield a finding
    rather than crashing the run (rule id 'parse-error')."""
    found: List[Finding] = []
    for fp in iter_py_files(paths):
        with open(fp, "r", encoding="utf-8") as fh:
            src = fh.read()
        try:
            found.extend(analyze_source(src, fp, rules))
        except SyntaxError as e:
            found.append(Finding(rule="parse-error", path=fp,
                                 line=e.lineno or 0, severity="error",
                                 message=f"file does not parse: {e.msg}"))
    return found


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return list(data.get("findings", []))


def save_baseline(path: str, findings: Sequence[Finding],
                  notes: Optional[Dict[Tuple[str, str, str], str]] = None
                  ) -> None:
    entries = []
    for f in findings:
        e = {"rule": f.rule, "path": f.path.replace(os.sep, "/"),
             "code": f.code, "message": f.message}
        if notes and f.key() in notes:
            e["note"] = notes[f.key()]
        entries.append(e)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"comment": "Accepted findings: python -m repro.analysis "
                              "--update-baseline. Each entry should carry a "
                              "one-line 'note' saying why it is deliberate.",
                   "findings": entries}, fh, indent=2)
        fh.write("\n")


def split_new(findings: Sequence[Finding], baseline: Sequence[dict]
              ) -> Tuple[List[Finding], List[Finding]]:
    """(new, baselined). Baseline entries match at most once each (multiset
    semantics: a second identical violation on another line is new)."""
    budget: Dict[Tuple[str, str, str], int] = {}
    for e in baseline:
        k = (e.get("rule", ""), e.get("path", ""), e.get("code", ""))
        budget[k] = budget.get(k, 0) + 1
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        k = f.key()
        if budget.get(k, 0) > 0:
            budget[k] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
