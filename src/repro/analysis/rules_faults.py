"""Fault-isolation rule: fault injection stays out of jit-traced bodies.

The chaos-smoke CI gate promises that ``FaultPlan.none()`` is BIT-EXACT
with ``faults=None`` on every execution path. That guarantee holds
because faults are resolved entirely on the host side: the event sim
(core/events.py) draws dispatch fates, parks crashed clients, and drops
corrupt deliveries *before* anything reaches the jit'd chunk — the
traced executable only ever sees dense committed batches and has no idea
faults exist.

A fault-plan read inside a traced body breaks that in one of two ways.
If the plan flows in as a Python object, its rates are frozen at trace
time — one plan's outcomes baked into the cached executable, silently
reused for every other plan (including the zero-fault run, which is how
the bit-exactness gate dies). If it flows in as a traced array, the
clean path pays the fault branch on every step, and the zero-overhead
contract dies instead. Either way the fix is the same: resolve faults in
the event sim and keep the traced function fault-blind.

This rule reuses the traced-body discovery from rules_obs (``@jax.jit``
decorations, ``jax.jit(f)`` wrappings, lax control-flow body arguments)
and flags any reference to the fault vocabulary inside one.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis import astutil
from repro.analysis.core import FileContext, Finding, Rule
from repro.analysis.rules_obs import traced_bodies

# anything importable from the fault subsystem
_FAULT_MODULE = "repro.core.faults"

# identifiers that ARE fault state, wherever they appear: the plan types,
# the per-dispatch fate resolver, and the config knobs that only exist to
# parameterize fault handling
_FAULT_ATTRS = {"faults", "fault_plan", "dispatch_fates", "kill_round",
                "quorum_timeout", "max_retries"}
_FAULT_NAMES = {"FaultPlan", "ResolvedFaults", "parse_faults",
                "record_checksum"} | _FAULT_ATTRS


class FaultIsolation(Rule):
    id = "fault-isolation"
    doc = ("fault-plan state (FaultPlan, dispatch_fates, sfl.faults, "
           "quorum_timeout, ...) referenced inside a jit/scan-traced body "
           "— fault outcomes are host-side DES control flow; a trace-time "
           "read bakes one plan into the cached executable (or makes the "
           "clean path pay the fault branch) and breaks the zero-fault "
           "bit-exactness gate. Resolve faults in core/events.py and keep "
           "the traced chunk fault-blind.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for body in traced_bodies(ctx):
            for n in ast.walk(body):
                ref = self._fault_ref(ctx, n)
                if ref:
                    yield self.finding(
                        ctx, n,
                        f"fault-plan reference '{ref}' inside a traced "
                        "body — fault handling is host-side event-sim "
                        "logic; a trace-time read freezes one plan's "
                        "outcomes into the cached executable and breaks "
                        "the zero-fault bit-exactness gate. Resolve "
                        "faults in core/events.py and pass the traced "
                        "function only committed batches.")

    def _fault_ref(self, ctx: FileContext, n: ast.AST) -> Optional[str]:
        if isinstance(n, ast.Attribute):
            # sfl.faults / plan.dispatch_fates / carry.quorum_timeout
            if n.attr in _FAULT_ATTRS:
                return astutil.dotted_name(n) or f".{n.attr}"
            resolved = astutil.resolve_name(n, ctx.aliases)
            if resolved and resolved.startswith(_FAULT_MODULE + "."):
                return resolved
        elif isinstance(n, ast.Name):
            # fault_plan.crash flags via its base name; but when the parent
            # attribute is itself fault vocabulary (sfl.faults), that node
            # already reports — don't double up
            parent = getattr(n, "parent", None)
            if isinstance(parent, ast.Attribute):
                pres = astutil.resolve_name(parent, ctx.aliases)
                if parent.attr in _FAULT_ATTRS or (
                        pres and pres.startswith(_FAULT_MODULE + ".")):
                    return None
            resolved = astutil.resolve_name(n, ctx.aliases) or n.id
            if resolved == _FAULT_MODULE \
                    or resolved.startswith(_FAULT_MODULE + "."):
                return n.id
            if n.id in _FAULT_NAMES:
                return n.id
        return None
