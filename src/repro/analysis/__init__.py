"""repro.analysis — invariant-aware static analysis for this repo.

``python -m repro.analysis src/`` lints the tree against the invariants
the CI equivalence gates rest on (RNG discipline, jit-cache discipline,
host-sync-free streamed loops, donation safety, Pallas/SMEM budgets,
mesh-axis-valid PartitionSpecs), exits non-zero on any finding not in
``analysis/baseline.json`` and not suppressed inline with
``# lint: ignore[rule-id]``. See README "Invariants & static analysis".
"""
from repro.analysis.core import (Finding, Rule, analyze_paths,
                                 analyze_source, default_rules,
                                 load_baseline, save_baseline, split_new)

__all__ = ["Finding", "Rule", "analyze_paths", "analyze_source",
           "default_rules", "load_baseline", "save_baseline", "split_new",
           "check_clean"]


def check_clean(paths, baseline_path: str = "analysis/baseline.json"):
    """(new_findings, baselined) for ``paths`` — the programmatic gate
    bench_timeline --smoke and the CI job share with the CLI."""
    findings = analyze_paths(list(paths))
    return split_new(findings, load_baseline(baseline_path))
