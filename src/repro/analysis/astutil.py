"""Shared AST machinery for the analysis rules.

Rules work on plain ``ast`` trees with two extras provided here: parent
links (``node.parent``) so a rule can ask "am I inside a loop / a lambda
passed to ``_cached_jit``?", and import-alias resolution so ``np.random
.default_rng`` and ``numpy.random.default_rng`` (or ``from jax import
random as jr; jr.split``) normalize to one canonical dotted name before
any rule matches on it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Union

FuncScope = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]
SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
LOOP_NODES = (ast.For, ast.While, ast.AsyncFor)


def attach_parents(tree: ast.AST) -> ast.AST:
    """Set ``child.parent`` for every node (module root has parent None)."""
    tree.parent = None                                   # type: ignore
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node                          # type: ignore
    return tree


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = getattr(node, "parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "parent", None)


def enclosing(node: ast.AST, kinds) -> Optional[ast.AST]:
    for anc in ancestors(node):
        if isinstance(anc, kinds):
            return anc
    return None


def in_loop(node: ast.AST, *, within: Optional[ast.AST] = None) -> bool:
    """True if ``node`` sits inside a for/while body, without crossing into
    a nested function scope (a closure defined in a loop runs once per
    *call*, not per iteration). ``within`` bounds the walk."""
    for anc in ancestors(node):
        if anc is within:
            return False
        if isinstance(anc, SCOPE_NODES):
            return False
        if isinstance(anc, LOOP_NODES):
            return True
    return False


def scope_walk(scope: ast.AST) -> Iterator[ast.AST]:
    """Descendants of ``scope`` that belong to it — nested function/lambda
    scopes are yielded but not entered (their bodies are someone else's
    scope)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, SCOPE_NODES):
            stack.extend(ast.iter_child_nodes(n))


def scope_nodes_ordered(scope: ast.AST) -> list:
    """scope_walk in source order (lineno, col)."""
    return sorted(scope_walk(scope), key=lambda n: (getattr(n, "lineno", 0),
                                                    getattr(n, "col_offset",
                                                            0)))


def collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to canonical dotted module paths.

    ``import numpy as np``            -> {'np': 'numpy'}
    ``import jax.numpy as jnp``       -> {'jnp': 'jax.numpy'}
    ``from jax import random as jr``  -> {'jr': 'jax.random'}
    ``from repro.kernels.zo_update import zo_replay_flat``
                                      -> {'zo_replay_flat': 'repro.kernels.
                                          zo_update.zo_replay_flat'}
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
                if a.asname:
                    aliases[a.asname] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_name(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Canonical dotted name of a Name/Attribute expression, with the
    leading segment resolved through the module's import aliases."""
    dn = dotted_name(node)
    if dn is None:
        return None
    head, _, rest = dn.partition(".")
    full = aliases.get(head, head)
    return f"{full}.{rest}" if rest else full


def call_name(call: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return resolve_name(call.func, aliases)


def assigned_names(target: ast.AST) -> Iterator[str]:
    """Flat names bound by an assignment target (tuples recursed)."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from assigned_names(elt)
    elif isinstance(target, ast.Starred):
        yield from assigned_names(target.value)


def const_int(node: ast.AST, consts: Dict[str, int]) -> Optional[int]:
    """Fold an expression to an int using module-level constants: literals,
    names, unary minus, and + - * // << arithmetic. None when dynamic."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, consts)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs = const_int(node.left, consts)
        rhs = const_int(node.right, consts)
        if lhs is None or rhs is None:
            return None
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.FloorDiv) and rhs != 0:
            return lhs // rhs
        if isinstance(node.op, ast.LShift):
            return lhs << rhs
    return None


def module_consts(tree: ast.AST) -> Dict[str, int]:
    """Module-level integer constants (top-level ``NAME = <int expr>``)."""
    consts: Dict[str, int] = {}
    for stmt in getattr(tree, "body", []):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = const_int(stmt.value, consts)
            if v is not None:
                consts[stmt.targets[0].id] = v
    return consts
