"""RNG rules: numpy/stdlib RNG discipline and JAX PRNG key reuse.

Everything bit-exact in this repo — scan == python, sparse == dense,
subset staging == fleet gather, checkpoint resume — reduces to RNG draws
happening in a pinned order from pinned keys. These rules reject the two
ways that discipline silently erodes: ambient RNG state (global numpy /
stdlib ``random``; unseeded generators) and a JAX key consumed twice.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.core import FileContext, Finding, Rule

# numpy.random attributes that are NOT legacy global-state samplers
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator", "PCG64",
    "PCG64DXSM", "Philox", "SFC64", "MT19937", "RandomState",
}

_STDLIB_RANDOM_OK = {"Random", "SystemRandom"}

# jax.random callables that *derive* keys rather than consuming them
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
                 "clone", "key_data"}
_NOT_SAMPLERS = _KEY_DERIVERS | {"key_impl", "default_prng_impl"}


def _mentions_seed(node: ast.AST) -> bool:
    """Does the expression reference a seed-named thing (``seed``,
    ``self.seed``, ``cfg.data_seed``...) anywhere?"""
    for n in ast.walk(node):
        name = None
        if isinstance(n, ast.Name):
            name = n.id
        elif isinstance(n, ast.Attribute):
            name = n.attr
        elif isinstance(n, ast.arg):
            name = n.arg
        if name is not None and "seed" in name.lower():
            return True
    return False


class RngDiscipline(Rule):
    id = "rng-discipline"
    doc = ("No ambient RNG state: numpy legacy global samplers "
           "(np.random.rand/seed/...) and stdlib random are banned; "
           "np.random.default_rng() must be seeded, and tuple seeds must "
           "lead with the run seed — the (seed, stream_tag, ...) keying "
           "convention of straggler.py / loader.py / synthetic.py.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = astutil.call_name(node, ctx.aliases)
            if name is None:
                continue
            if name.startswith("numpy.random."):
                attr = name.split(".", 2)[2]
                if attr.split(".")[0] not in _NP_RANDOM_OK:
                    yield self.finding(
                        ctx, node,
                        f"np.random.{attr} draws from the process-global "
                        "numpy RNG — schedules/batches stop being a pure "
                        "function of (seed, ...); use np.random.default_rng"
                        "((seed, stream_tag, ...)) instead")
                elif attr == "default_rng":
                    yield from self._check_default_rng(ctx, node)
            elif name.startswith("random.") and name.count(".") == 1:
                attr = name.split(".")[1]
                if attr not in _STDLIB_RANDOM_OK:
                    yield self.finding(
                        ctx, node,
                        f"stdlib random.{attr} uses hidden global state — "
                        "resume/equivalence gates cannot pin it; use a "
                        "seeded np.random.default_rng stream")

    def _check_default_rng(self, ctx: FileContext,
                           node: ast.Call) -> Iterable[Finding]:
        if not node.args and not node.keywords:
            yield self.finding(
                ctx, node,
                "np.random.default_rng() without a seed is entropy-seeded "
                "— every draw is unreproducible; key it as "
                "(seed, stream_tag, ...)")
            return
        arg = node.args[0] if node.args else node.keywords[0].value
        if isinstance(arg, (ast.Tuple, ast.List)):
            if not arg.elts:
                return
            if not _mentions_seed(arg.elts[0]):
                yield self.finding(
                    ctx, node,
                    "seed tuple does not lead with the run seed: the repo "
                    "keys streams as (seed, stream_tag, ...) so distinct "
                    "consumers stay decorrelated per run seed",
                    severity="warning")
        elif isinstance(arg, ast.Constant) and not _mentions_seed(node):
            yield self.finding(
                ctx, node,
                "hard-coded RNG seed: thread the run seed through instead "
                "(key streams as (seed, stream_tag, ...))",
                severity="warning")


class JaxKeyReuse(Rule):
    id = "jax-key-reuse"
    doc = ("A jax.random key passed to two sampling calls without an "
           "intervening split/fold_in yields correlated draws — flag the "
           "second consumption, and any consumption inside a loop of a "
           "key derived outside it.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        scopes: List[ast.AST] = [ctx.tree]
        scopes.extend(n for n in ast.walk(ctx.tree)
                      if isinstance(n, astutil.SCOPE_NODES))
        for scope in scopes:
            yield from self._check_scope(ctx, scope)

    # -- helpers ----------------------------------------------------------

    def _jax_random_attr(self, ctx: FileContext,
                         call: ast.Call) -> Optional[str]:
        name = astutil.call_name(call, ctx.aliases)
        if name and name.startswith("jax.random."):
            return name.split(".", 2)[2]
        return None

    def _check_scope(self, ctx: FileContext,
                     scope: ast.AST) -> Iterable[Finding]:
        # pass 1: key variables = names ever assigned from PRNGKey/split/
        # fold_in (or rebound from them in tuple unpacks)
        nodes = astutil.scope_nodes_ordered(scope)
        keys: Set[str] = set()
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for a in scope.args.args + scope.args.kwonlyargs:
                if a.arg == "key" or a.arg.endswith("_key"):
                    keys.add(a.arg)
        for n in nodes:
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
                attr = self._jax_random_attr(ctx, n.value)
                if attr in _KEY_DERIVERS:
                    for t in n.targets:
                        keys.update(astutil.assigned_names(t))
        if not keys:
            return

        def loop_depth(n: ast.AST) -> int:
            d = 0
            for anc in astutil.ancestors(n):
                if anc is scope or isinstance(anc, astutil.SCOPE_NODES):
                    break
                if isinstance(anc, astutil.LOOP_NODES):
                    d += 1
            return d

        # key names re-derived somewhere inside a loop advance their stream
        # per iteration — consuming them in that loop is the sanctioned
        # `key, sub = split(key)` idiom, whichever line order it uses
        refreshed_in_loop: Set[str] = set()
        for n in nodes:
            if isinstance(n, ast.Assign) and loop_depth(n) > 0:
                for t in n.targets:
                    refreshed_in_loop.update(
                        nm for nm in astutil.assigned_names(t) if nm in keys)
            elif isinstance(n, (ast.For, ast.AsyncFor)):
                refreshed_in_loop.update(
                    nm for nm in astutil.assigned_names(n.target)
                    if nm in keys)

        # pass 2: walk statements in order; track, per key name, the last
        # consuming call (absent = fresh)
        consumed: Dict[str, ast.Call] = {}
        for n in nodes:
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    for nm in astutil.assigned_names(t):
                        if nm in keys:
                            consumed.pop(nm, None)
            elif isinstance(n, (ast.For, ast.AsyncFor)):
                for nm in astutil.assigned_names(n.target):
                    if nm in keys:
                        consumed.pop(nm, None)
            elif isinstance(n, ast.Call):
                attr = self._jax_random_attr(ctx, n)
                if attr is None or attr in _NOT_SAMPLERS or not n.args:
                    continue
                k0 = n.args[0]
                if not isinstance(k0, ast.Name) or k0.id not in keys:
                    continue
                nm = k0.id
                prev = consumed.get(nm)
                if prev is not None:
                    yield self.finding(
                        ctx, n,
                        f"key '{nm}' already consumed by jax.random call on "
                        f"line {prev.lineno} — split/fold_in before sampling "
                        "again (identical keys give identical draws)")
                elif loop_depth(n) > 0 and nm not in refreshed_in_loop:
                    yield self.finding(
                        ctx, n,
                        f"key '{nm}' derived outside this loop is consumed "
                        "inside it — every iteration samples the same "
                        "stream; fold_in the loop index first")
                consumed[nm] = n
