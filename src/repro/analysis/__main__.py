"""CLI: python -m repro.analysis [paths...] [options].

Exit status: 0 when every finding is baselined or suppressed, 1 when new
findings exist (the CI contract), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import core


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant-aware static analysis (RNG discipline, "
                    "jit-cache/trace leaks, host syncs, donation safety, "
                    "Pallas budgets, PartitionSpec axes).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--baseline", default="analysis/baseline.json",
                    help="accepted-findings file (default: "
                         "analysis/baseline.json)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write ALL current findings to the baseline and "
                         "exit 0 (add a 'note' per entry afterwards)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write findings (new + baselined) as JSON")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids + what invariant each protects")
    ap.add_argument("--no-baseline", action="store_true",
                    help="treat every finding as new (audit mode)")
    args = ap.parse_args(argv)

    rules = core.default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id}\n    {r.doc}")
        return 0
    if args.rules:
        wanted = {s.strip() for s in args.rules.split(",")}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"unknown rule id(s): {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    paths = args.paths or ["src"]
    findings = core.analyze_paths(paths, rules)

    if args.update_baseline:
        core.save_baseline(args.baseline, findings)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    baseline = [] if args.no_baseline else core.load_baseline(args.baseline)
    new, old = core.split_new(findings, baseline)

    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump({"new": [f.to_json() for f in new],
                       "baselined": [f.to_json() for f in old]},
                      fh, indent=2)
            fh.write("\n")

    for f in new:
        print(f.render())
    tail = (f", {len(old)} baselined" if old else "")
    if new:
        print(f"\n{len(new)} new finding(s){tail} — fix them, suppress "
              "with '# lint: ignore[rule-id]', or accept via "
              "--update-baseline (with a rationale note)")
        return 1
    print(f"analysis clean: 0 new findings{tail} "
          f"({len(core.iter_py_files(paths))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
