"""Telemetry-purity rule: observability must stay on the host side.

The measured telemetry producer's whole contract (obs/telemetry.py) is
that its clock reads bracket *dispatch*, never live inside it.  A
``float()`` / ``.item()`` coercion inside a jit'd body forces a
device->host sync at trace time (or, worse, silently bakes the traced
value into the executable); a ``span()`` / ``perf_counter()`` /
``time.time()`` probe inside a traced body runs ONCE at trace time and
then never again — the "measurement" it records is compile-time, not
run-time, and it stops firing entirely once the executable is cached.
Either way the number is a lie and the jit boundary is compromised.

This rule finds traced bodies — functions decorated with ``@jax.jit``
(bare or via ``partial``), functions passed to ``jax.jit(f)`` /
``lax.scan(body, ...)`` / ``lax.fori_loop`` / ``lax.while_loop`` in the
same file, and jit'd lambdas — and flags host coercions and obs probes
inside them.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis import astutil
from repro.analysis.core import FileContext, Finding, Rule

_JIT_NAMES = {"jax.jit", "jax.pmap", "jax.experimental.pjit.pjit"}
# control-flow combinators whose body argument is traced exactly once
_TRACED_BODY_ARGS = {
    "jax.lax.scan": (0,),
    "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2),
    "jax.lax.map": (0,),
}
_PARTIAL_NAMES = {"functools.partial", "partial"}

# host-sync coercions (same vocabulary as rules_jit.HostSync, but here ANY
# occurrence inside a traced body is wrong, looped or not)
_COERCIONS = {"float", "int", "bool", "complex"}
_NP_COERCIONS = {"numpy.asarray", "numpy.array", "numpy.float64",
                 "numpy.float32", "numpy.int64"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}

# obs probes and wall clocks: trace-time side effects, not measurements
_PROBE_NAMES = {
    "repro.obs.span", "repro.obs.trace.span",
    "repro.obs.measure", "repro.obs.measure.measure",
    "repro.obs.get_registry", "repro.obs.metrics.get_registry",
    "time.perf_counter", "time.perf_counter_ns", "time.time",
    "time.monotonic",
}


def _is_jit_call(ctx: FileContext, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = astutil.call_name(node, ctx.aliases)
    if name in _JIT_NAMES:
        return True
    # partial(jax.jit, static_argnums=...)(f) / @partial(jax.jit, ...)
    if name in _PARTIAL_NAMES and node.args:
        return astutil.resolve_name(node.args[0], ctx.aliases) in _JIT_NAMES
    return False


def traced_bodies(ctx: FileContext) -> List[ast.AST]:
    """Every function/lambda in the file whose body jit traces: ``@jax.jit``
    decorations (bare or via partial), ``jax.jit(f)`` wrappings of same-file
    defs and lambdas, and the body arguments of the lax control-flow
    combinators. Shared by every rule that polices what may live inside a
    traced body (telemetry-purity, fault-isolation)."""
    defs = {}                       # name -> FunctionDef (same file)
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(n.name, n)

    out: List[ast.AST] = []
    seen: Set[int] = set()

    def add(node: Optional[ast.AST]) -> None:
        if node is None or id(node) in seen:
            return
        if isinstance(node, ast.Lambda):
            seen.add(id(node))
            out.append(node)
        elif isinstance(node, ast.Name) and node.id in defs:
            fn = defs[node.id]
            if id(fn) not in seen:
                seen.add(id(fn))
                out.append(fn)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            seen.add(id(node))
            out.append(node)

    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                if astutil.resolve_name(dec, ctx.aliases) in _JIT_NAMES \
                        or _is_jit_call(ctx, dec):
                    add(n)
        if not isinstance(n, ast.Call):
            continue
        if _is_jit_call(ctx, n):
            for a in n.args:
                add(a)              # jax.jit(f) / jax.jit(lambda ...)
        name = astutil.call_name(n, ctx.aliases)
        for i in _TRACED_BODY_ARGS.get(name or "", ()):
            if i < len(n.args):
                add(n.args[i])
    return out


class TelemetryPurity(Rule):
    id = "telemetry-purity"
    doc = ("float()/.item() host-sync coercions and obs probes (span, "
           "perf_counter, metrics) inside a jit/scan-traced body either "
           "force a device sync at trace time or fire once at trace time "
           "and never again — instrument at the dispatch boundary "
           "(engine chunk loop), never inside the traced function.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for body in traced_bodies(ctx):
            yield from self._check_body(ctx, body)

    # -- violations inside one traced body ------------------------------

    def _check_body(self, ctx: FileContext,
                    body: ast.AST) -> Iterable[Finding]:
        for n in ast.walk(body):
            if isinstance(n, ast.withitem):
                call = n.context_expr
                if isinstance(call, ast.Call) and self._probe(ctx, call):
                    yield self.finding(
                        ctx, call,
                        f"obs probe '{self._probe(ctx, call)}' inside a "
                        "traced body fires once at trace time, then never "
                        "again — move it to the dispatch boundary")
            if not isinstance(n, ast.Call):
                continue
            name = astutil.call_name(n, ctx.aliases)
            probe = self._probe(ctx, n)
            if probe and not isinstance(getattr(n, "parent", None),
                                        ast.withitem):
                yield self.finding(
                    ctx, n,
                    f"obs probe '{probe}' inside a traced body fires once "
                    "at trace time, then never again — move it to the "
                    "dispatch boundary")
            elif name in _COERCIONS or name in _NP_COERCIONS:
                if n.args:
                    yield self.finding(
                        ctx, n,
                        f"{name}() inside a traced body forces a host "
                        "sync at trace time and bakes the traced value "
                        "into the executable — return the array and "
                        "coerce at the chunk-boundary flush")
            elif isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _SYNC_METHODS:
                yield self.finding(
                    ctx, n,
                    f".{n.func.attr}() inside a traced body is a "
                    "trace-time host sync — the engine's only sanctioned "
                    "sync is the per-chunk flush outside jit")

    def _probe(self, ctx: FileContext, call: ast.Call) -> Optional[str]:
        name = astutil.call_name(call, ctx.aliases)
        if name in _PROBE_NAMES:
            return name
        # repro.obs.span / repro.obs.trace.span via any import alias ends
        # with obs.<probe>; also catch sink.emit / registry probes by attr
        if name and (name.endswith(".span") and "obs" in name.split(".")):
            return name
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in {"emit", "observe", "inc"} \
                and isinstance(call.func.value, ast.Name) \
                and call.func.value.id in {"telemetry", "sink", "tracer",
                                           "registry", "metrics"}:
            return f".{call.func.attr}()"
        return None
