"""Kernel-budget and sharding-spec rules.

Pallas kernels fail at *lowering* (or worse, at runtime on a different
chip) when a BlockSpec violates the TPU tiling grid or a scratch/operand
footprint exceeds the per-core memories; PartitionSpecs fail at pjit time
when an axis name doesn't exist on the mesh. Both are knowable from the
source: block shapes here are module-level constants, and the repo's mesh
axes are a closed set ('pod', 'data', 'model' — launch/mesh.py,
launch/fleet.py's ('data',) fleet mesh).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from repro.analysis import astutil
from repro.analysis.core import FileContext, Finding, Rule

# per-core budgets (TPU generations vary; these are the conservative
# floors the kernels are written against — see /opt guides + kernels/
# zo_update.py's own comments: VMEM ~16 MiB, SMEM tens of KiB)
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
SMEM_BUDGET_BYTES = 64 * 1024
# f32 tiling grid: last dim multiple of 128 lanes, second-to-last of 8
LANE_MULTIPLE = 128
SUBLANE_MULTIPLE = 8

# the repo's declared mesh axes (sharding/specs.py DEFAULT_AXIS_SIZES,
# launch/mesh.py, launch/fleet.py)
MESH_AXES = frozenset({"pod", "data", "model"})

# raw kernel entry points whose SMEM chunking lives in kernels/ops.py —
# calling them anywhere else bypasses the REPLAY_SMEM_RECORDS budget
_RAW_KERNELS = {"repro.kernels.zo_update.zo_replay_flat",
                "repro.kernels.zo_update.zo_update_flat"}
_BUDGET_LAYER = "repro/kernels/"

_BLOCKSPEC_NAMES = {"pl.BlockSpec", "pallas.BlockSpec",
                    "jax.experimental.pallas.BlockSpec"}
_PSPEC_NAMES = {"jax.sharding.PartitionSpec",
                "jax.experimental.pjit.PartitionSpec"}


class PallasBudget(Rule):
    id = "pallas-budget"
    doc = ("Static SMEM/VMEM footprints and BlockSpec tiling for Pallas "
           "kernels (REPLAY_SMEM_RECORDS-style budgets), plus "
           "PartitionSpec axis names validated against the declared mesh "
           "axes {'pod','data','model'}.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        yield from self._check_blockspecs(ctx)
        yield from self._check_smem_budget_consts(ctx)
        yield from self._check_raw_kernel_calls(ctx)
        yield from self._check_pspecs(ctx)

    # -- BlockSpec tiling + VMEM footprint --------------------------------

    def _blockspec_dims(self, ctx: FileContext, call: ast.Call
                        ) -> Optional[List[Optional[int]]]:
        shape = call.args[0] if call.args else None
        for kw in call.keywords:
            if kw.arg == "block_shape":
                shape = kw.value
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return None
        return [astutil.const_int(e, ctx.consts) for e in shape.elts]

    def _is_smem_spec(self, ctx: FileContext, call: ast.Call) -> bool:
        for kw in call.keywords:
            if kw.arg == "memory_space":
                name = astutil.resolve_name(kw.value, ctx.aliases) or ""
                return name.endswith(".SMEM") or name == "SMEM"
        return False

    def _check_blockspecs(self, ctx: FileContext) -> Iterable[Finding]:
        per_call_vmem: List[Tuple[ast.Call, int]] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = astutil.call_name(node, ctx.aliases) or ""
            if name not in _BLOCKSPEC_NAMES \
                    and not name.endswith(".BlockSpec"):
                continue
            dims = self._blockspec_dims(ctx, node)
            if dims is None:
                continue
            smem = self._is_smem_spec(ctx, node)
            if smem:
                known = [d for d in dims if d is not None]
                if known:
                    bytes_ = 4
                    for d in known:
                        bytes_ *= d
                    if bytes_ > SMEM_BUDGET_BYTES:
                        yield self.finding(
                            ctx, node,
                            f"SMEM BlockSpec holds ~{bytes_} B > the "
                            f"{SMEM_BUDGET_BYTES} B per-core scalar-memory "
                            "budget — chunk the operand (the "
                            "REPLAY_SMEM_RECORDS pattern in kernels/ops.py)")
                continue
            if len(dims) >= 2 and all(d is not None for d in dims):
                if dims[-1] % LANE_MULTIPLE != 0:
                    yield self.finding(
                        ctx, node,
                        f"BlockSpec last dim {dims[-1]} is not a multiple "
                        f"of the {LANE_MULTIPLE}-lane tile — the block "
                        "cannot map onto TPU vector registers")
                elif dims[-2] % SUBLANE_MULTIPLE != 0:
                    yield self.finding(
                        ctx, node,
                        f"BlockSpec sublane dim {dims[-2]} is not a "
                        f"multiple of {SUBLANE_MULTIPLE} (f32 tile is "
                        f"{SUBLANE_MULTIPLE}x{LANE_MULTIPLE})")
                else:
                    bytes_ = 4
                    for d in dims:
                        bytes_ *= d
                    per_call_vmem.append((node, bytes_))
        if per_call_vmem:
            total = sum(b for _, b in per_call_vmem)
            # double-buffered pipelining: each block is resident twice
            if 2 * total > VMEM_BUDGET_BYTES:
                yield self.finding(
                    ctx, per_call_vmem[0][0],
                    f"VMEM block footprint ~{2 * total} B (double-"
                    f"buffered) exceeds the {VMEM_BUDGET_BYTES} B per-core "
                    "budget — shrink the block rows")

    # -- SMEM record-list budget constants --------------------------------

    def _check_smem_budget_consts(self, ctx: FileContext
                                  ) -> Iterable[Finding]:
        """Any module-level *_SMEM_RECORDS constant must fit the SMEM
        budget at 8 B/record (seed u32 + coeff f32), the zo_replay wire
        format."""
        for stmt in getattr(ctx.tree, "body", []):
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                continue
            name = stmt.targets[0].id
            if not name.endswith("_SMEM_RECORDS"):
                continue
            val = astutil.const_int(stmt.value, ctx.consts)
            if val is not None and val * 8 > SMEM_BUDGET_BYTES:
                yield self.finding(
                    ctx, stmt,
                    f"{name} = {val} records x 8 B = {val * 8} B exceeds "
                    f"the {SMEM_BUDGET_BYTES} B SMEM budget — the kernel "
                    "will fail at lowering on real cores")

    # -- raw kernel calls outside the budget-enforcing layer --------------

    def _check_raw_kernel_calls(self, ctx: FileContext) -> Iterable[Finding]:
        if _BUDGET_LAYER in ctx.path.replace("\\", "/"):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = astutil.call_name(node, ctx.aliases)
            if name in _RAW_KERNELS:
                yield self.finding(
                    ctx, node,
                    f"{name.split('.')[-1]} called outside kernels/ — the "
                    "raw kernel has no record chunking, so lists past "
                    "REPLAY_SMEM_RECORDS fail at lowering; call "
                    "ops.zo_replay_leaf / ops.zo_update_leaf instead")

    # -- PartitionSpec axis names -----------------------------------------

    def _check_pspecs(self, ctx: FileContext) -> Iterable[Finding]:
        pspec_locals = {local for local, full in ctx.aliases.items()
                        if full in _PSPEC_NAMES}
        if not pspec_locals:
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in pspec_locals):
                continue
            axes: List[str] = []
            for arg in node.args:
                elts = (arg.elts if isinstance(arg, (ast.Tuple, ast.List))
                        else [arg])
                for e in elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        axes.append(e.value)
            for ax in axes:
                if ax not in MESH_AXES:
                    yield self.finding(
                        ctx, node,
                        f"PartitionSpec axis '{ax}' is not a declared mesh "
                        f"axis {sorted(MESH_AXES)} — pjit will reject it "
                        "at placement time")
