"""jit-discipline rules: trace leaks, host syncs in streamed loops, and
donation safety.

The engine's perf ladder rests on three properties of how jit is used:
executables are cached per (algo, cfg, sfl) instead of re-traced per call
(`_cached_jit`, `decode_step_jit`); nothing inside the chunked scan /
sparse stream loop forces a device->host sync (the only sanctioned sync
is the per-chunk `flush`); and buffers listed in ``donate_argnums`` are
dead after the call. PR 4's trace-count regression test catches the first
dynamically — these rules catch all three at review time.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.core import FileContext, Finding, Rule

_JIT_NAMES = {"jax.jit", "jax.pmap", "jax.experimental.pjit.pjit"}
_CACHE_DECORATORS = {"functools.lru_cache", "functools.cache", "lru_cache",
                     "cache"}
# registries the engine routes jit construction through — a jax.jit inside
# a lambda/def handed to one of these is cached, not leaked
_JIT_REGISTRIES = {"_cached_jit"}

# host-sync coercions: calls that force the device stream to flush
_COERCIONS = {"float", "int", "bool", "complex"}
_NP_COERCIONS = {"numpy.asarray", "numpy.array", "numpy.float64",
                 "numpy.float32", "numpy.int64"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


def _resolved(ctx: FileContext, node: ast.AST) -> Optional[str]:
    return astutil.resolve_name(node, ctx.aliases)


def _is_jit_call(ctx: FileContext, node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and astutil.call_name(node, ctx.aliases) in _JIT_NAMES)


class TraceLeak(Rule):
    id = "trace-leak"
    doc = ("jax.jit(...) constructed inside a function body re-traces on "
           "every call (jit caches by function identity, which a fresh "
           "closure defeats) — route it through the _cached_jit / "
           "decode_step_jit registries, an lru_cache'd builder, or a "
           "module-level registry store.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not _is_jit_call(ctx, node):
                continue
            scope = astutil.enclosing(node, astutil.SCOPE_NODES)
            if scope is None:
                continue                       # module-level: traced once
            if self._via_registry(ctx, node):
                continue
            if self._cached_builder(ctx, node, scope):
                continue
            yield self.finding(
                ctx, node,
                "jax.jit constructed inside a function body — every call "
                "re-traces and re-compiles; go through _cached_jit / a "
                "module-level registry (the bug PR 4's trace-count "
                "regression test catches dynamically)")

    def _via_registry(self, ctx: FileContext, node: ast.AST) -> bool:
        """Inside a lambda/def passed as an argument to _cached_jit(...)."""
        child = node
        for anc in astutil.ancestors(node):
            if isinstance(anc, ast.Call):
                name = astutil.call_name(anc, ctx.aliases) or ""
                if name.split(".")[-1] in _JIT_REGISTRIES \
                        and child is not anc.func:
                    return True
            child = anc
        return False

    def _cached_builder(self, ctx: FileContext, node: ast.AST,
                        scope: ast.AST) -> bool:
        """The enclosing function memoizes: decorated with lru_cache/cache,
        or it stores the jit result into a subscripted registry
        (``_REG[key] = fn`` — the decode_step_jit pattern)."""
        fns = [a for a in [scope, *astutil.ancestors(scope)]
               if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))]
        if not fns:
            return False
        fn = fns[0]
        for dec in fn.decorator_list:
            d = dec.func if isinstance(dec, ast.Call) else dec
            if _resolved(ctx, d) in _CACHE_DECORATORS:
                return True
        # names the jit result is bound to inside this function
        bound: Set[str] = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign) and n.value is node:
                for t in n.targets:
                    bound.update(astutil.assigned_names(t))
        if not bound:
            return False
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    if isinstance(t, ast.Subscript) \
                            and isinstance(n.value, ast.Name) \
                            and n.value.id in bound:
                        return True
        return False


def _jitted_bindings(ctx: FileContext, scope: ast.AST
                     ) -> Dict[str, Optional[ast.Call]]:
    """Names in ``scope`` bound to a jit'd callable: direct ``v = jax.jit
    (...)``, via the registry ``v = _cached_jit(..., lambda: jax.jit(...))``,
    from a ``*_jit`` factory (``step = decode_step_jit(cfg)``), or a
    ``*_jit``-named parameter. Maps name -> the jax.jit call when visible
    (for donate_argnums inspection), else the factory call or None."""
    out: Dict[str, ast.Call] = {}
    if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
        # a parameter named like a jit'd callable (step_jit, chunk_jit)
        # is one by contract — callers hand in cached executables
        for a in scope.args.args + scope.args.kwonlyargs:
            if a.arg.endswith("_jit"):
                out[a.arg] = None       # no jit call to inspect
    for n in astutil.scope_walk(scope):
        if not (isinstance(n, ast.Assign) and isinstance(n.value, ast.Call)):
            continue
        call = n.value
        jit_call: Optional[ast.Call] = None
        if _is_jit_call(ctx, call):
            jit_call = call
        else:
            name = astutil.call_name(call, ctx.aliases) or ""
            tail = name.split(".")[-1]
            if tail in _JIT_REGISTRIES:
                for sub in ast.walk(call):
                    if sub is not call and _is_jit_call(ctx, sub):
                        jit_call = sub
                        break
                jit_call = jit_call or call
            elif tail.endswith("_jit"):
                jit_call = call
        if jit_call is not None:
            for t in n.targets:
                for nm in astutil.assigned_names(t):
                    out[nm] = jit_call
    return out


class HostSync(Rule):
    id = "host-sync"
    doc = ("float()/int()/bool()/.item()/np.asarray() applied inside a "
           "for/while loop to a value returned by a jit'd executable "
           "blocks the async dispatch stream every iteration — the "
           "engine's only sanctioned sync is the per-chunk flush at the "
           "loop boundary.")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        module_jitted = _jitted_bindings(ctx, ctx.tree)
        scopes = [ctx.tree] + [n for n in ast.walk(ctx.tree)
                               if isinstance(n, astutil.SCOPE_NODES)]
        for scope in scopes:
            yield from self._check_scope(ctx, scope, module_jitted)

    def _check_scope(self, ctx: FileContext, scope: ast.AST,
                     module_jitted: Dict[str, Optional[ast.Call]]
                     ) -> Iterable[Finding]:
        jitted = dict(module_jitted) if scope is not ctx.tree else {}
        jitted.update(_jitted_bindings(ctx, scope))
        if not jitted:
            return
        # taint: names assigned (incl. tuple-unpacked) from a jitted call
        tainted: Set[str] = set()
        for n in astutil.scope_walk(scope):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
                f = n.value.func
                if isinstance(f, ast.Name) and f.id in jitted:
                    for t in n.targets:
                        tainted.update(astutil.assigned_names(t))
        if not tainted:
            return

        def is_tainted(e: ast.AST) -> bool:
            while isinstance(e, (ast.Subscript, ast.Attribute)):
                e = e.value
            return isinstance(e, ast.Name) and e.id in tainted

        for n in astutil.scope_walk(scope):
            if not isinstance(n, ast.Call):
                continue
            if not astutil.in_loop(n, within=scope):
                continue
            name = astutil.call_name(n, ctx.aliases)
            hit = None
            if name in _COERCIONS or name in _NP_COERCIONS:
                if n.args and is_tainted(n.args[0]):
                    hit = name
            elif isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _SYNC_METHODS \
                    and is_tainted(n.func.value):
                hit = f".{n.func.attr}()"
            if hit:
                yield self.finding(
                    ctx, n,
                    f"{hit} on a jit output inside the loop forces a "
                    "device->host sync per iteration — keep the loop "
                    "async and sync once at the chunk boundary (flush)")


class DonationSafety(Rule):
    id = "donation-safety"
    doc = ("An argument passed at a donate_argnums position is invalidated "
           "by the call — reading that variable afterwards touches a "
           "deleted buffer (jit'd code may have aliased it to the output).")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        module_jitted = _jitted_bindings(ctx, ctx.tree)
        scopes = [ctx.tree] + [n for n in ast.walk(ctx.tree)
                               if isinstance(n, astutil.SCOPE_NODES)]
        for scope in scopes:
            yield from self._check_scope(ctx, scope, module_jitted)

    def _donated_argnums(self, jit_call: ast.Call) -> Tuple[int, ...]:
        for kw in jit_call.keywords:
            if kw.arg == "donate_argnums":
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    out = []
                    for e in kw.value.elts:
                        if isinstance(e, ast.Constant) \
                                and isinstance(e.value, int):
                            out.append(e.value)
                    return tuple(out)
                if isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, int):
                    return (kw.value.value,)
        return ()

    def _check_scope(self, ctx: FileContext, scope: ast.AST,
                     module_jitted: Dict[str, Optional[ast.Call]]
                     ) -> Iterable[Finding]:
        jitted = dict(module_jitted) if scope is not ctx.tree else {}
        jitted.update(_jitted_bindings(ctx, scope))
        donators: Dict[str, Tuple[int, ...]] = {}
        for nm, jit_call in jitted.items():
            nums = self._donated_argnums(jit_call) if jit_call is not None \
                else ()
            if nums:
                donators[nm] = nums
        if not donators:
            return
        nodes = [n for n in astutil.scope_nodes_ordered(scope)
                 if hasattr(n, "lineno")]
        # donated[name] = the donating Call node; cleared on reassignment
        donated: Dict[str, ast.Call] = {}
        for n in nodes:
            if isinstance(n, ast.Assign):
                rebound = set()
                for t in n.targets:
                    rebound.update(astutil.assigned_names(t))
                call = n.value if isinstance(n.value, ast.Call) else None
                self._note_call(call, donators, donated, rebound)
                for nm in rebound:
                    donated.pop(nm, None)
            elif isinstance(n, ast.Expr) and isinstance(n.value, ast.Call):
                self._note_call(n.value, donators, donated, set())
            elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id in donated:
                call = donated[n.id]
                if any(anc is call for anc in astutil.ancestors(n)):
                    continue        # the donating call's own argument
                yield self.finding(
                    ctx, n,
                    f"'{n.id}' was donated to the jit'd call on line "
                    f"{call.lineno} — its buffer may already be reused; "
                    "copy before the call or rebind the result")
                donated.pop(n.id, None)         # one finding per donation

    def _note_call(self, call: Optional[ast.Call],
                   donators: Dict[str, Tuple[int, ...]],
                   donated: Dict[str, ast.Call],
                   rebound: Set[str]) -> None:
        if call is None or not isinstance(call.func, ast.Name):
            return
        nums = donators.get(call.func.id)
        if not nums:
            return
        for i in nums:
            if i < len(call.args) and isinstance(call.args[i], ast.Name):
                nm = call.args[i].id
                if nm not in rebound:
                    donated[nm] = call
