"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run everywhere
(CPU containers execute the kernel bodies in interpret mode; TPU compiles
them). Pytree-level helpers flatten/pad leaves into the kernels' (R, LANE)
layout and give each leaf a disjoint slice of the counter space, so the
noise stream is identical regardless of leaf boundaries or sharding.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.zo_update import (BLOCK_ROWS, LANE, zo_replay_flat,
                                     zo_update_flat)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret):
    return (not on_tpu()) if interpret is None else interpret


# ---------------------------------------------------------------------------
# leaf + pytree ZO update
# ---------------------------------------------------------------------------

def zo_update_leaf(x: jnp.ndarray, seed, coeff, *, row_offset: int = 0,
                   interpret=None) -> jnp.ndarray:
    """y = x + coeff·u(seed) for an arbitrary-shaped leaf (pads to LANE).
    ``row_offset`` positions the leaf in the (row, lane) counter space."""
    interpret = _auto_interpret(interpret)
    n = x.size
    rows = -(-n // LANE)
    flat = jnp.pad(x.reshape(-1), (0, rows * LANE - n)).reshape(rows, LANE)
    out = zo_update_flat(flat, seed, coeff, offset=row_offset,
                         interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


# per-leaf seed decorrelation — MUST stay in sync with zo._LEAF_SALT so a
# record written by the engine (zo.tree_noise dist='counter') replays here
# on the identical stream
_LEAF_SALT = 0x9E3779B9


def zo_update_tree(params: Any, seed, coeff, *, interpret=None) -> Any:
    """Fused seed-replay update over a whole pytree. Leaf i draws from its
    own salted seed (seed ^ i·φ) at row offset 0 — the exact stream of
    zo.tree_noise(dist='counter'), so ``zo_update_tree(p,
    zo.record_seeds(key), -c)`` equals ``zo.apply_update(p, key, c,
    'counter')``."""
    leaves, treedef = jax.tree.flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        leaf_seed = (jnp.asarray(seed, jnp.uint32)
                     ^ jnp.uint32((i * _LEAF_SALT) & 0xFFFFFFFF))
        out.append(zo_update_leaf(leaf, leaf_seed, coeff,
                                  interpret=interpret))
    return jax.tree.unflatten(treedef, out)


def zo_perturb_tree(params: Any, seed, eps, *, interpret=None) -> Any:
    """x + eps·u — the perturbation side of SPSA (same noise stream)."""
    return zo_update_tree(params, seed, eps, interpret=interpret)


# ---------------------------------------------------------------------------
# batched seed replay (perf-ladder v4 hot path)
# ---------------------------------------------------------------------------

# zo_replay_flat keeps (seeds, coeffs) in SMEM: 8 B per record. SMEM is
# tens of KiB per core, so the record list is bounded — past this many
# records the ops layer splits the list and sweeps the leaf once per
# chunk (ceil(N/bound) sweeps) instead of failing at lowering.
REPLAY_SMEM_RECORDS = 2048            # 2048 × 8 B = 16 KiB of SMEM


def zo_replay_leaf(x: jnp.ndarray, seeds, coeffs, *, row_offset: int = 0,
                   impl: str = "auto", interpret=None,
                   max_records: int = 0) -> jnp.ndarray:
    """y = x + Σᵢ coeffs[i]·u(seeds[i]) for an arbitrary-shaped leaf —
    one read + one write of x regardless of N, as long as the (seeds,
    coeffs) list fits the kernel's SMEM budget. Longer lists (N = M·τ·P
    past ``REPLAY_SMEM_RECORDS``) are chunked here at the ops layer: each
    chunk is one fused sweep, so an oversized replay costs ceil(N/bound)
    parameter sweeps rather than a lowering failure.

    impl='auto' picks the compiled Pallas kernel on TPU and the pure-JAX
    reference elsewhere (an interpret-mode Pallas sweep over N records is
    needlessly slow on CPU); 'pallas'/'ref' force a backend for the
    equivalence tests. ``max_records`` overrides the SMEM bound (tests)."""
    if impl == "auto":
        impl = "pallas" if on_tpu() else "ref"
    if impl == "ref":
        return _ref.zo_replay_ref(x, seeds, coeffs, row_offset=row_offset)
    assert impl == "pallas", impl
    interpret = _auto_interpret(interpret)
    seeds = jnp.asarray(seeds, jnp.uint32).reshape(-1)
    coeffs = jnp.asarray(coeffs, jnp.float32).reshape(-1)
    bound = max_records or REPLAY_SMEM_RECORDS
    n = x.size
    rows = -(-n // LANE)
    # pad the row count to a whole number of grid blocks (the extra rows
    # draw unused counter noise and are sliced off below)
    block = min(BLOCK_ROWS, rows)
    rows = -(-rows // block) * block
    flat = jnp.pad(x.reshape(-1), (0, rows * LANE - n)).reshape(rows, LANE)
    for i in range(0, seeds.shape[0], bound):
        flat = zo_replay_flat(flat, seeds[i:i + bound], coeffs[i:i + bound],
                              offset=row_offset, interpret=interpret)
    return flat.reshape(-1)[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# rmsnorm / flash attention
# ---------------------------------------------------------------------------

def rmsnorm_op(x, scale, *, eps: float = 1e-5, interpret=None):
    return rmsnorm(x, scale, eps=eps, interpret=_auto_interpret(interpret))


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       interpret=None, **kw):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_auto_interpret(interpret), **kw)
