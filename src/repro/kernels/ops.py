"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run everywhere
(CPU containers execute the kernel bodies in interpret mode; TPU compiles
them). Pytree-level helpers flatten/pad leaves into the kernels' (R, LANE)
layout and give each leaf a disjoint slice of the counter space, so the
noise stream is identical regardless of leaf boundaries or sharding.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.zo_update import LANE, zo_update_flat


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret):
    return (not on_tpu()) if interpret is None else interpret


# ---------------------------------------------------------------------------
# leaf + pytree ZO update
# ---------------------------------------------------------------------------

def zo_update_leaf(x: jnp.ndarray, seed, coeff, *, row_offset: int = 0,
                   interpret=None) -> jnp.ndarray:
    """y = x + coeff·u(seed) for an arbitrary-shaped leaf (pads to LANE).
    ``row_offset`` positions the leaf in the (row, lane) counter space."""
    interpret = _auto_interpret(interpret)
    n = x.size
    rows = -(-n // LANE)
    flat = jnp.pad(x.reshape(-1), (0, rows * LANE - n)).reshape(rows, LANE)
    out = zo_update_flat(flat, seed, coeff, offset=row_offset,
                         interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


def zo_update_tree(params: Any, seed, coeff, *, interpret=None) -> Any:
    """Fused seed-replay update over a whole pytree. Each leaf gets a
    disjoint counter ROW range (stable in tree structure; 2^32 rows × 1024
    lanes of stream space — enough for multi-trillion-parameter trees)."""
    leaves, treedef = jax.tree.flatten(params)
    out = []
    row = 0
    for leaf in leaves:
        rows = -(-leaf.size // LANE)
        out.append(zo_update_leaf(leaf, seed, coeff, row_offset=row,
                                  interpret=interpret))
        row += rows
    return jax.tree.unflatten(treedef, out)


def zo_perturb_tree(params: Any, seed, eps, *, interpret=None) -> Any:
    """x + eps·u — the perturbation side of SPSA (same noise stream)."""
    return zo_update_tree(params, seed, eps, interpret=interpret)


# ---------------------------------------------------------------------------
# rmsnorm / flash attention
# ---------------------------------------------------------------------------

def rmsnorm_op(x, scale, *, eps: float = 1e-5, interpret=None):
    return rmsnorm(x, scale, eps=eps, interpret=_auto_interpret(interpret))


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       interpret=None, **kw):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_auto_interpret(interpret), **kw)
