"""Flash-attention forward Pallas TPU kernel (causal / sliding-window, GQA).

ZO training needs NO attention backward — the paper's gradient-free design
means the flash *forward* alone covers the training hot path (a structural
simplification vs first-order flash kernels).

Canonical TPU blocking: grid (B·H, S_q/BQ, S_k/BK); the kv dim is the
innermost (sequential) grid axis, so running max / sum / accumulator live in
VMEM scratch across kv steps. Per-step working set:
    q (BQ, d) + k (BK, d) + v (BK, d) + acc (BQ, d) + scores (BQ, BK)
With BQ=BK=128, d<=256 in f32 that is < 0.6 MiB — comfortably inside the
~16 MiB VMEM budget, and the (128, 128) score tile is MXU-shaped.

Causal + sliding-window masking is block-sparse: kv blocks wholly outside
the band are skipped via @pl.when (no MXU work, no HBM traffic for skipped
v loads in the compiled path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = iq * bq                       # first query row of this block
    k_lo = ik * bk
    # block-level relevance: any (r, c) with c <= r (causal) and r-c < window
    relevant = True
    if causal:
        relevant = k_lo <= q_lo + bq - 1
    if window > 0:
        relevant = jnp.logical_and(relevant,
                                   (q_lo - (k_lo + bk - 1)) < window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= cols <= rows
        if window > 0:
            ok &= (rows - cols) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, d); k, v: (B, Hkv, S, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / np.sqrt(d)

    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * Hkv, S, d)
    vf = v.reshape(B * Hkv, S, d)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // G, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),    # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
