"""Blocked RMSNorm Pallas TPU kernel.

Row-blocked: each grid step normalizes BLOCK_ROWS rows of width D entirely
in VMEM (one HBM read + one write; mean-square reduction and rescale fused —
no intermediate variance tensor in HBM). f32 math, output in input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
            block_rows: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: (..., D) -> (..., D). Rows are processed in VMEM blocks."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    rows = min(block_rows, R)
    # pad rows to a block multiple
    pad = (-R) % rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // rows,),
        in_specs=[
            pl.BlockSpec((rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
