"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
for the interpret-mode sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# counter-based RNG (murmur3-finalizer hash -> Box-Muller gaussian)
# shared formula between ref and kernel: u[i] = gauss(seed, i)
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLD = np.uint32(0x9E3779B9)


def _hash_u32(seed, idx):
    """Murmur3 finalizer over (seed + idx*golden). uint32 arrays."""
    x = (idx * _GOLD + seed).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = (x * _M1).astype(jnp.uint32)
    x = x ^ (x >> 13)
    x = (x * _M2).astype(jnp.uint32)
    x = x ^ (x >> 16)
    return x


def counter_gauss(seed: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Standard normal from two independent hashes via Box-Muller (f32)."""
    seed = jnp.asarray(seed, jnp.uint32)
    idx = jnp.asarray(idx, jnp.uint32)
    h1 = _hash_u32(seed, idx)
    h2 = _hash_u32(seed ^ np.uint32(0xA5A5A5A5), idx)
    # u1 in (0,1]: avoid log(0); u2 in [0,1)
    u1 = (h1.astype(jnp.float32) + 1.0) * (1.0 / 4294967296.0)
    u2 = h2.astype(jnp.float32) * (1.0 / 4294967296.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(2.0 * jnp.float32(jnp.pi) * u2)


def counter_gauss2(seed, hi, lo) -> jnp.ndarray:
    """2-D counter gaussian: (hi, lo) index pair — 2^64-element streams for
    >4B-parameter trees. hi/lo are uint32 arrays broadcast together."""
    seed = jnp.asarray(seed, jnp.uint32)
    mixed = (jnp.asarray(hi, jnp.uint32) * _M1 + seed).astype(jnp.uint32)
    return counter_gauss(mixed, jnp.asarray(lo, jnp.uint32))


LANE = 1024


def noise_rows(seed, row0: int, n_rows: int) -> jnp.ndarray:
    """(n_rows, LANE) standard-normal block; row r uses counter row0+r.
    The canonical noise layout shared by zo.tree_noise (dist='counter'),
    zo_update_ref, and the Pallas kernel."""
    hi = (jnp.arange(n_rows, dtype=jnp.uint32) + jnp.uint32(row0))[:, None]
    lo = jnp.arange(LANE, dtype=jnp.uint32)[None, :]
    return counter_gauss2(seed, jnp.broadcast_to(hi, (n_rows, LANE)),
                          jnp.broadcast_to(lo, (n_rows, LANE)))


# ---------------------------------------------------------------------------
# zo_update oracle: y = x + coeff * u over the (row, LANE) counter layout
# ---------------------------------------------------------------------------

def zo_update_ref(x: jnp.ndarray, seed, coeff, row_offset: int = 0
                  ) -> jnp.ndarray:
    n = x.size
    rows = -(-n // LANE)
    u = noise_rows(seed, row_offset, rows).reshape(-1)[:n].reshape(x.shape)
    return (x.astype(jnp.float32) + jnp.asarray(coeff, jnp.float32) * u
            ).astype(x.dtype)


# windowed-accumulation width: the Σ cᵢ·uᵢ accumulator is built as a
# lax.scan over windows of this many unrolled records. A flat N-record
# unroll fuses into one giant elementwise XLA fusion whose live noise
# temporaries scale with N (32.2 GB temp at N=32 on the fake 16×16 CPU
# mesh — perf_iterations.json v5 vs v5.1); the windowed scan bounds the
# fusion (and the temp footprint) at WINDOW records while still touching
# x exactly once. Accumulation order is identical to the sequential
# record order, so results are bit-identical to the old flat unroll.
_REPLAY_WINDOW = 8


def zo_replay_ref(x: jnp.ndarray, seeds, coeffs, row_offset: int = 0
                  ) -> jnp.ndarray:
    """Batched-replay oracle: y = x + Σᵢ coeffs[i]·u(seeds[i]).

    Matches zo_replay_flat (and N sequential zo_update_ref applications up
    to f32 summation order): the Σ cᵢ·uᵢ accumulator is built elementwise
    BEFORE x is touched, so the parameter leaf is read and written exactly
    once regardless of N. Above _REPLAY_WINDOW records the accumulation
    runs as a scan of WINDOW-record unrolled windows (records padded with
    zero coefficients to a whole window)."""
    seeds = jnp.asarray(seeds, jnp.uint32).reshape(-1)
    coeffs = jnp.asarray(coeffs, jnp.float32).reshape(-1)
    n = seeds.shape[0]
    n_el = x.size
    rows = -(-n_el // LANE)
    hi = ((jnp.arange(rows, dtype=jnp.uint32) + jnp.uint32(row_offset))
          [:, None] + jnp.zeros((rows, LANE), jnp.uint32))
    lo = jnp.broadcast_to(jnp.arange(LANE, dtype=jnp.uint32)[None, :],
                          (rows, LANE))
    W = _REPLAY_WINDOW
    if n <= W:
        acc = jnp.zeros((rows, LANE), jnp.float32)
        for i in range(n):
            acc = acc + coeffs[i] * counter_gauss2(seeds[i], hi, lo)
    else:
        pad = (-n) % W                 # zero-coeff records contribute +0
        gs = jnp.pad(seeds, (0, pad)).reshape(-1, W)
        gc = jnp.pad(coeffs, (0, pad)).reshape(-1, W)

        def body(acc, sc):
            s, c = sc
            for j in range(W):
                acc = acc + c[j] * counter_gauss2(s[j], hi, lo)
            return acc, None

        acc, _ = jax.lax.scan(body, jnp.zeros((rows, LANE), jnp.float32),
                              (gs, gc))
    acc = acc.reshape(-1)[:n_el].reshape(x.shape)
    return (x.astype(jnp.float32) + acc).astype(x.dtype)


# ---------------------------------------------------------------------------
# rmsnorm oracle
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention oracle (causal / sliding-window, GQA)
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B, H, S, d); k, v: (B, Hkv, S, d). Returns (B, H, S, d)."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window > 0:
        ok &= (i - j) < window
    scores = jnp.where(ok, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, d).astype(q.dtype)
