"""Fused SPSA perturb/update/replay Pallas TPU kernels.

The ZO training hot loop sweeps every parameter 2τ+3 times per round with
``x ± λu`` / ``x ← x − a·u``. A naive implementation reads x AND a
materialized u from HBM (two reads + one write). These kernels regenerate u
*inside VMEM* from a counter-based hash (murmur3 finalizer + Box-Muller —
identical formula to ref.counter_gauss), making the op one HBM read + one
write (1.5× traffic reduction) and eliminating parameter-sized noise
storage entirely — the TPU realization of MeZO-style seed replay adapted to
the HBM→VMEM hierarchy.

Two entry points:
  zo_update_flat   y = x + c·u(seed)            (single record)
  zo_replay_flat   y = x + Σᵢ cᵢ·u(seedᵢ)       (batched seed replay)

``zo_replay_flat`` is the aggregation hot path (perf-ladder v4): replaying
the N = M·τ·P records of a seed-replay round as a lax.scan of single-record
updates costs N full HBM read+write sweeps of the parameters; the batched
kernel holds each (rows, LANE) block in VMEM, accumulates all N
counter-gaussian contributions there ((seeds, coeffs) live in SMEM), and
touches HBM once per block regardless of N — O(1) parameter sweeps instead
of O(Mτ P).

Layout: the caller flattens a leaf to (R, LANE) rows of 1024 lanes; the
grid walks row blocks; each block derives its global element indices from
program_id, so the noise stream is independent of blocking/sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024          # elements per row (8 × 128 VREG tiles)
BLOCK_ROWS = 256     # rows per grid step: 256 × 1024 × 4 B = 1 MiB VMEM

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLD = np.uint32(0x9E3779B9)
# LANE must stay in sync with kernels/ref.py (shared counter layout)


def _hash_u32(seed, idx):
    x = (idx * _GOLD + seed).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = (x * _M1).astype(jnp.uint32)
    x = x ^ (x >> 13)
    x = (x * _M2).astype(jnp.uint32)
    x = x ^ (x >> 16)
    return x


def _gauss2(seed, hi, lo):
    """2-D counter gaussian — identical formula to ref.counter_gauss2."""
    mixed = (hi * _M1 + seed).astype(jnp.uint32)
    h1 = _hash_u32(mixed, lo)
    h2 = _hash_u32(mixed ^ np.uint32(0xA5A5A5A5), lo)
    u1 = (h1.astype(jnp.float32) + 1.0) * (1.0 / 4294967296.0)
    u2 = h2.astype(jnp.float32) * (1.0 / 4294967296.0)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(
        2.0 * jnp.float32(np.pi) * u2)


def _zo_update_kernel(seed_ref, coeff_ref, x_ref, o_ref, *, offset: int):
    i = pl.program_id(0)
    rows, lane = x_ref.shape
    row0 = jnp.uint32(offset) + jnp.uint32(i) * jnp.uint32(rows)
    hi = row0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, lane), 0)
    lo = jax.lax.broadcasted_iota(jnp.uint32, (rows, lane), 1)
    u = _gauss2(seed_ref[0], hi, lo)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x + coeff_ref[0] * u).astype(o_ref.dtype)


def zo_update_flat(x_flat: jnp.ndarray, seed: jnp.ndarray,
                   coeff: jnp.ndarray, *, offset: int = 0,
                   interpret: bool = False) -> jnp.ndarray:
    """y = x + coeff · u(seed) over a flat (R, LANE) f32/bf16 array.
    ``offset`` is the ROW offset into the (row, lane) counter space."""
    R, lane = x_flat.shape
    assert lane == LANE, f"lane dim must be {LANE}"
    rows = min(BLOCK_ROWS, R)
    assert R % rows == 0
    grid = (R // rows,)
    return pl.pallas_call(
        functools.partial(_zo_update_kernel, offset=offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_flat.shape, x_flat.dtype),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.uint32).reshape(1),
      jnp.asarray(coeff, jnp.float32).reshape(1), x_flat)


def _zo_replay_kernel(seeds_ref, coeffs_ref, x_ref, o_ref, *, offset: int,
                      n: int):
    i = pl.program_id(0)
    rows, lane = x_ref.shape
    row0 = jnp.uint32(offset) + jnp.uint32(i) * jnp.uint32(rows)
    hi = row0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, lane), 0)
    lo = jax.lax.broadcasted_iota(jnp.uint32, (rows, lane), 1)

    def body(j, acc):
        return acc + coeffs_ref[j] * _gauss2(seeds_ref[j], hi, lo)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((rows, lane), jnp.float32))
    o_ref[...] = (x_ref[...].astype(jnp.float32) + acc).astype(o_ref.dtype)


def zo_replay_flat(x_flat: jnp.ndarray, seeds: jnp.ndarray,
                   coeffs: jnp.ndarray, *, offset: int = 0,
                   interpret: bool = False) -> jnp.ndarray:
    """y = x + Σᵢ coeffs[i]·u(seeds[i]) over a flat (R, LANE) f32/bf16 array.

    The batched form of ``zo_update_flat``: the N counter-gaussian noise
    contributions are regenerated and summed in VMEM, so the whole replay is
    one HBM read + one write per block regardless of N. seeds/coeffs are
    (N,) SMEM-resident scalars; ``offset`` is the ROW offset into the
    (row, lane) counter space (same stream as zo_update_flat)."""
    R, lane = x_flat.shape
    assert lane == LANE, f"lane dim must be {LANE}"
    seeds = jnp.asarray(seeds, jnp.uint32).reshape(-1)
    coeffs = jnp.asarray(coeffs, jnp.float32).reshape(-1)
    n = seeds.shape[0]
    assert coeffs.shape[0] == n, (coeffs.shape, n)
    rows = min(BLOCK_ROWS, R)
    assert R % rows == 0
    grid = (R // rows,)
    return pl.pallas_call(
        functools.partial(_zo_replay_kernel, offset=offset, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((n,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_flat.shape, x_flat.dtype),
        interpret=interpret,
    )(seeds, coeffs, x_flat)
