"""Counters / gauges / histograms registry.

The aggregate face of the observability layer: spans and RoundTelemetry
are per-event records; metrics are the cheap running totals a CLI flag
(`train.py --telemetry`) or a serving stats endpoint (`serve.py`) can
print at any moment without walking the ring buffers.

Deliberately tiny and dependency-free:

  Counter    monotonically increasing float (``inc``)
  Gauge      last-written value (``set``)
  Histogram  streaming count/sum/min/max + fixed log-spaced buckets
             (``observe``) — enough for latency tails without reservoirs

All instruments are created through a ``MetricsRegistry`` so a snapshot
is one dict, JSON-ready. A process-wide registry is available via
``get_registry()`` for the launch layer; libraries should accept a
registry argument instead of importing the global.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, List, Optional, Tuple

# Default histogram buckets: log-spaced seconds, 1µs .. 100s.
_DEFAULT_BUCKETS = tuple(m * (10.0 ** e) for e in range(-6, 3)
                         for m in (1.0, 2.5, 5.0))


class Counter:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"Counter {self.name}: negative inc {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self._value}


class Gauge:
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self._value}


class Histogram:
    __slots__ = ("name", "buckets", "_counts", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str,
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.buckets = tuple(sorted(buckets or _DEFAULT_BUCKETS))
        self._counts = [0] * (len(self.buckets) + 1)  # +1 = overflow
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile from bucket upper bounds (the standard
        histogram-quantile estimate; exact enough for latency tails)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self._count == 0:
            return 0.0
        rank = q * self._count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                return self.buckets[i] if i < len(self.buckets) else self._max
        return self._max

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "histogram", "count": self._count,
                "sum": self._sum, "mean": self.mean,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Name → instrument map; instruments are create-or-get so call sites
    don't coordinate. Names collide across kinds deliberately (an error):
    one name, one meaning."""

    def __init__(self):
        self._instruments: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        return self._get(name, Histogram, buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            insts = dict(self._instruments)
        return {name: inst.snapshot() for name, inst in sorted(insts.items())}

    def clear(self) -> None:
        with self._lock:
            self._instruments.clear()


_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry used by the launch layer."""
    return _GLOBAL
