"""The one benchmark measurement helper: (seconds, peak_bytes).

Extracted from benchmarks/bench_timeline.py so every perf row in every
benchmark records wall time and peak host allocation identically:
gc.collect() first (so a prior row's garbage doesn't count against this
one), tracemalloc around the call (peak Python-heap bytes — device
buffers are invisible here by design; those are accounted by staging_bytes
in RoundTelemetry), perf_counter for wall seconds.

tracemalloc adds real overhead — use this for benchmark rows, never on
the engine hot path (that's what obs.trace spans are for).
"""
from __future__ import annotations

import gc
import tracemalloc
from time import perf_counter
from typing import Any, Callable, NamedTuple


class Measurement(NamedTuple):
    result: Any
    seconds: float
    peak_bytes: int


def measure(fn: Callable[..., Any], *args, **kwargs) -> Measurement:
    """Run ``fn(*args, **kwargs)`` and return (result, seconds, peak_bytes).
    Exception-safe: tracemalloc is stopped even when fn raises (a
    benchmark arm that refuses to run must not poison the next row)."""
    gc.collect()
    tracemalloc.start()
    t0 = perf_counter()
    try:
        out = fn(*args, **kwargs)
        dt = perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    return Measurement(out, dt, peak)
