"""Nestable span tracer over the engine hot path.

Design constraints, in order:

1. **Near-zero cost when disabled.** Every instrumentation point calls the
   module-level ``span(name, **attrs)``; when no enabled tracer is
   installed it returns one shared no-op context manager — the cost is a
   global load, an attribute check, and the kwargs dict Python builds
   anyway. No allocation, no clock read, no lock. The engine's CI overhead
   gate (benchmarks/bench_telemetry.py) holds the *enabled* path to <= 2%
   on the sparse timeline; the disabled path is gated by a unit test.
2. **Thread-safe nesting.** The engine's host side is single-threaded
   today, but checkpointing is async and multi-host fleets won't be: the
   span stack is thread-local (so ``depth``/parent attribution is per
   thread) and the finished-record list is appended under a lock.
3. **Standard exports.** ``export_chrome`` writes the Chrome trace-event
   JSON (load in chrome://tracing or https://ui.perfetto.dev);
   ``export_jsonl`` writes one span per line for ad-hoc processing.

Spans measure HOST time (time.perf_counter). Device work is measured by
bracketing dispatch with ``jax.block_until_ready`` at chunk boundaries —
inside jit-traced code a span would fire at trace time only, which is why
the ``telemetry-purity`` lint rule forbids probes there.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional


class SpanRecord(NamedTuple):
    """One finished span."""
    name: str
    start: float         # perf_counter seconds at entry
    duration: float      # seconds
    thread: int          # OS thread ident
    depth: int           # nesting depth within its thread (0 = top level)
    attrs: Dict[str, Any]


class _NullSpan:
    """The shared disabled-path context manager: enters and exits for free
    and swallows nothing (exceptions propagate)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):             # symmetric API with _Span
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span; created only when the tracer is enabled."""
    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "SpanTracer", name: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (e.g. bytes staged)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        tracer = self._tracer
        tracer._stack().pop()
        rec = SpanRecord(self.name, self._t0, t1 - self._t0,
                         threading.get_ident(), self._depth, self.attrs)
        with tracer._lock:
            tracer._records.append(rec)
        return False


class SpanTracer:
    """Collects SpanRecords; install one with ``obs.trace.install``."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._records: List[SpanRecord] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def records(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- exports ----------------------------------------------------------

    def export_jsonl(self, path: str) -> int:
        """One span per line: {name, start, duration, thread, depth, attrs}.
        Returns the number of spans written."""
        recs = self.records()
        with open(path, "w", encoding="utf-8") as fh:
            for r in recs:
                fh.write(json.dumps(r._asdict()) + "\n")
        return len(recs)

    def export_chrome(self, path: str) -> int:
        """Chrome trace-event format ('X' complete events, µs timebase) —
        loadable in chrome://tracing / perfetto. Returns the span count."""
        recs = self.records()
        events = [{"name": r.name, "ph": "X", "pid": 0, "tid": r.thread,
                   "ts": r.start * 1e6, "dur": r.duration * 1e6,
                   "args": r.attrs} for r in recs]
        with open(path, "w", encoding="utf-8") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, fh)
        return len(recs)


# ---------------------------------------------------------------------------
# the module-level instrumentation surface
# ---------------------------------------------------------------------------

_ACTIVE: Optional[SpanTracer] = None


def install(tracer: Optional[SpanTracer]) -> Optional[SpanTracer]:
    """Install (or, with None, remove) the process-wide tracer; returns the
    previously installed one so callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    return prev


def get_tracer() -> Optional[SpanTracer]:
    return _ACTIVE


def span(name: str, **attrs):
    """The hot-path probe: ``with span('engine.chunk', r0=r0): ...``.
    Free when no enabled tracer is installed."""
    t = _ACTIVE
    if t is None or not t.enabled:
        return _NULL_SPAN
    return _Span(t, name, attrs)
