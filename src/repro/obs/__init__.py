"""Observability substrate: telemetry records, span tracing, metrics.

The ROADMAP's sim-to-real seam: every control decision in the engine
(AdaptiveTau re-planning τ, future cut×τ co-planners) historically read
the schedule's *simulated* delays — nothing observed what the hardware
actually did. This package is the measurement layer both the simulator
and the real engine feed:

  telemetry   RoundTelemetry (per-chunk durations, quorum waits,
              per-cohort arrival latencies, staging bytes, host-prefetch
              vs device-scan overlap) + TelemetrySink, a ring-buffer hub
              with named producers — the simulator is just one of them.
  trace       a nestable, thread-safe span tracer (perf_counter) with
              Chrome-trace / JSONL export, near-zero-cost when disabled,
              installed over the engine hot path (chunk dispatch, DES
              streaming, subset staging, fleet placement).
  metrics     a counters/gauges/histograms registry surfaced by
              launch/train.py (--telemetry) and launch/serve.py (stats).
  measure     the (seconds, peak_bytes) perf_counter + tracemalloc
              helper every benchmark row is measured with.
  runlog      structured JSONL run log (per-round rows + per-chunk
              telemetry), resume-safe (never duplicates rounds).

Nothing here imports jax or the engine: probes are host-side and read at
chunk boundaries only — the `telemetry-purity` lint rule
(repro.analysis) enforces that no probe or host-sync coercion lands
inside a jit-traced body.
"""
from repro.obs.measure import Measurement, measure
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry)
from repro.obs.runlog import RunLog, read_jsonl
from repro.obs.telemetry import RoundTelemetry, TelemetrySink
from repro.obs.trace import SpanRecord, SpanTracer, get_tracer, install, span

__all__ = [
    "Measurement", "measure",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "RunLog", "read_jsonl",
    "RoundTelemetry", "TelemetrySink",
    "SpanRecord", "SpanTracer", "get_tracer", "install", "span",
]
