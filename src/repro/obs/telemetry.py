"""RoundTelemetry + TelemetrySink: the measured record the control loop
reads.

The engine historically had exactly one account of time: the schedule's
*simulated* delays, consumed by AdaptiveTau through SchedWindow. This
module makes that one producer among several. A ``RoundTelemetry`` record
describes a contiguous window of rounds (sync) or versions (async) from
ONE producer's point of view:

  source='sim'       the simulator: per-round durations are the
                     wall-clock model's round times (bit-identical to
                     ChunkInfo.round_times — gated in tests), quorum
                     waits come from the compiled/streamed timeline, and
                     per-cohort arrival latencies are derived from the
                     schedule's delay + uplink rows.
  source='measured'  the measured clock: chunk dispatch bracketed by
                     jax.block_until_ready, host staging time
                     (DES chunk generation + _stack_sparse_chunk), bytes
                     staged, and the host-prefetch time that overlapped
                     the device scan.

``TelemetrySink`` is the hub: a bounded ring buffer (deque) the engine
emits into and controllers read from via ``SchedWindow.telemetry``. A
served deployment replaces the simulator producer with real arrival
measurements without touching the controller — that is the sim-to-real
seam.

Records are immutable; array fields are numpy arrays compared bit-for-bit
in the equivalence gates. ``durations`` is always per-round/(C,): the
measured producer spreads the chunk wall time uniformly across its C
rounds, so windows concatenate cleanly across chunk boundaries.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as np


class RoundTelemetry(NamedTuple):
    """One producer's account of rounds [start, stop)."""
    start: int                       # first round/version in the window
    stop: int                        # one past the last
    source: str                      # 'sim' | 'measured' | external
    mode: str                        # engine mode: 'scan'|'python'|'async'
    durations: np.ndarray            # (C,) per-round seconds
    quorum_wait: Optional[np.ndarray] = None   # (C,) async quorum waits
    cohort_arrival: Optional[np.ndarray] = None  # (n_cohorts,) mean
    #                                  arrival latency (delay + uplink) of
    #                                  the window's active clients
    staging_seconds: float = 0.0     # host time staging chunk batches
    staging_bytes: int = 0           # bytes staged for the chunk
    dispatch_seconds: float = 0.0    # block_until_ready-bracketed chunk
    #                                  dispatch wall time
    overlap_seconds: float = 0.0     # host prefetch time overlapped with
    #                                  the device scan (sparse streaming)
    t_wall: float = 0.0              # time.time() at emission
    # fault / degradation counters over the window (core/faults.py): how
    # many dispatched contributions were lost to each cause, plus ring
    # evictions (contribution loss under ring pressure), retransmissions,
    # deduped duplicate deliveries, started dispatches, and commits forced
    # by the quorum_timeout deadline. All zero on a zero-fault run.
    started: int = 0                 # dispatches incl. faulted fetches
    evicted: int = 0                 # ring-store evict-oldest drops
    crashed: int = 0                 # crash-after-fetch
    lost: int = 0                    # all delivery attempts lost
    corrupt: int = 0                 # checksum-dropped payloads
    dups: int = 0                    # duplicate deliveries (deduped)
    retries: int = 0                 # retransmissions consumed
    timeouts: int = 0                # quorum_timeout-forced commits

    @property
    def n_rounds(self) -> int:
        return self.stop - self.start

    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable form (runlog / CI artifacts)."""
        def arr(a):
            return None if a is None else [float(x) for x in np.asarray(a)]
        return {"start": int(self.start), "stop": int(self.stop),
                "source": self.source, "mode": self.mode,
                "durations": arr(self.durations),
                "quorum_wait": arr(self.quorum_wait),
                "cohort_arrival": arr(self.cohort_arrival),
                "staging_seconds": float(self.staging_seconds),
                "staging_bytes": int(self.staging_bytes),
                "dispatch_seconds": float(self.dispatch_seconds),
                "overlap_seconds": float(self.overlap_seconds),
                "t_wall": float(self.t_wall),
                "started": int(self.started),
                "evicted": int(self.evicted),
                "crashed": int(self.crashed), "lost": int(self.lost),
                "corrupt": int(self.corrupt), "dups": int(self.dups),
                "retries": int(self.retries),
                "timeouts": int(self.timeouts)}


def _stamp(rec: RoundTelemetry) -> RoundTelemetry:
    return rec if rec.t_wall else rec._replace(t_wall=time.time())


class TelemetrySink:
    """Bounded ring-buffer hub for RoundTelemetry records.

    Thread-safe: producers ``emit`` under a lock (the async checkpointer
    and future per-host producers share the sink); readers get snapshot
    lists. Capacity bounds memory on long runs — a window query only ever
    needs the last few chunks, and the JSONL run log persists the rest.
    """

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError(f"TelemetrySink capacity must be > 0, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._emitted = 0

    def emit(self, rec: RoundTelemetry) -> None:
        with self._lock:
            self._ring.append(_stamp(rec))
            self._emitted += 1

    @property
    def emitted(self) -> int:
        """Total records ever emitted (>= len(records()) once the ring
        wraps)."""
        return self._emitted

    def records(self, source: Optional[str] = None) -> List[RoundTelemetry]:
        with self._lock:
            recs = list(self._ring)
        if source is not None:
            recs = [r for r in recs if r.source == source]
        return recs

    def window(self, start: int, stop: int,
               source: Optional[str] = None) -> Tuple[RoundTelemetry, ...]:
        """Records overlapping rounds [start, stop), oldest first — what
        the engine attaches to SchedWindow.telemetry."""
        return tuple(r for r in self.records(source)
                     if r.start < stop and r.stop > start)

    def latest(self, source: Optional[str] = None
               ) -> Optional[RoundTelemetry]:
        recs = self.records(source)
        return recs[-1] if recs else None

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def summary(self) -> Dict[str, Any]:
        """Aggregate view for run-end reporting / the stats surface."""
        recs = self.records()
        out: Dict[str, Any] = {"emitted": self._emitted,
                               "buffered": len(recs), "sources": {}}
        for src in sorted({r.source for r in recs}):
            rs = [r for r in recs if r.source == src]
            durs = np.concatenate([np.asarray(r.durations, np.float64)
                                   for r in rs]) if rs else np.zeros(0)
            s: Dict[str, Any] = {
                "records": len(rs),
                "rounds": int(sum(r.n_rounds for r in rs)),
                "total_duration_s": float(durs.sum()),
                "mean_round_s": float(durs.mean()) if durs.size else 0.0,
                "staging_seconds": float(sum(r.staging_seconds
                                             for r in rs)),
                "staging_bytes": int(sum(r.staging_bytes for r in rs)),
                "dispatch_seconds": float(sum(r.dispatch_seconds
                                              for r in rs)),
                "overlap_seconds": float(sum(r.overlap_seconds
                                             for r in rs)),
            }
            qw = [np.asarray(r.quorum_wait, np.float64) for r in rs
                  if r.quorum_wait is not None]
            if qw:
                allq = np.concatenate(qw)
                s["mean_quorum_wait_s"] = float(allq.mean())
            faults = {f: int(sum(getattr(r, f) for r in rs))
                      for f in ("started", "evicted", "crashed", "lost",
                                "corrupt", "dups", "retries", "timeouts")}
            if any(faults[f] for f in faults if f != "started"):
                s["faults"] = faults
            out["sources"][src] = s
        return out
