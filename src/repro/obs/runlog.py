"""Structured JSONL run log for launch/train.py.

One JSON object per line, two row kinds:

  {"kind": "round", "round": r, "loss": ..., "tau": ...}
  {"kind": "chunk", "start": r0, "stop": r1, "telemetry": [RoundTelemetry
   .to_json(), ...], "metrics": {...}}

Resume safety: a checkpoint at round k restarts training at round k+1,
but the previous process may have logged rounds past k before dying (the
engine runs ahead of ckpt_every-aligned chunk boundaries). On open with
``resume_round=k+1`` the log is truncated to rows strictly before the
restart point — round rows with round < resume_round, chunk rows with
stop <= resume_round — so re-run rounds are never duplicated. Truncation
rewrites via a temp file + os.replace, so a crash mid-truncate leaves
either the old or the new log, never a torn one.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional


def _keep_on_resume(row: Dict[str, Any], resume_round: int) -> bool:
    kind = row.get("kind")
    if kind == "round":
        return row.get("round", -1) < resume_round
    if kind == "chunk":
        return row.get("stop", -1) <= resume_round
    return True    # unknown kinds (headers, notes) are preserved


class RunLog:
    """Append-only JSONL writer with resume-safe truncation."""

    def __init__(self, path: str, resume_round: int = 0,
                 log_every: int = 1):
        if log_every < 1:
            raise ValueError(f"log_every must be >= 1, got {log_every}")
        self.path = path
        self.log_every = int(log_every)
        if resume_round > 0 and os.path.exists(path):
            kept = [r for r in read_jsonl(path)
                    if _keep_on_resume(r, resume_round)]
            tmp = path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                for row in kept:
                    fh.write(json.dumps(row) + "\n")
            os.replace(tmp, path)
        self._fh = open(path, "a", encoding="utf-8")

    def write(self, row: Dict[str, Any]) -> None:
        self._fh.write(json.dumps(row) + "\n")
        self._fh.flush()

    def round(self, round_idx: int, **fields) -> None:
        """Log one round row, honouring log_every (round 0 always logs)."""
        if round_idx % self.log_every == 0:
            self.write({"kind": "round", "round": int(round_idx), **fields})

    def chunk(self, start: int, stop: int,
              telemetry: Iterable[Any] = (), **fields) -> None:
        """Log one chunk row; telemetry items are RoundTelemetry records
        (serialized via .to_json()) or plain dicts."""
        tel = [t.to_json() if hasattr(t, "to_json") else dict(t)
               for t in telemetry]
        self.write({"kind": "chunk", "start": int(start), "stop": int(stop),
                    "telemetry": tel, **fields})

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def read_jsonl(path: str, kind: Optional[str] = None
               ) -> List[Dict[str, Any]]:
    """Read a JSONL log back; optionally filter by row kind. Tolerates a
    trailing partial line (crash mid-write)."""
    rows: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue
            if kind is None or row.get("kind") == kind:
                rows.append(row)
    return rows
