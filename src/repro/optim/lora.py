"""LoRA adapters for the FedLoRA baseline (paper Fig. 4 memory comparison).

Adapters target the attention projections (wq/wv) of every unit. The
adapter tree mirrors the param tree sparsely: {unit_key: {"b<j>": {"core":
{"wq": (A, B), "wv": (A, B)}}}} with A: (n_units, in, r), B: (n_units, r,
out). ``apply_lora`` materializes W + (α/r)·A@B before the forward — grads
w.r.t. (A, B) flow through jax.grad on the composed function.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

TARGETS = ("wq", "wv")


def init_lora(cfg: ModelConfig, params, rank: int, key) -> Dict:
    lora: Dict = {}
    units = params["units"]
    out_units: Dict = {}
    for bkey, block in units.items():
        core = block.get("core", {})
        hit = {t: core[t] for t in TARGETS if isinstance(core, dict) and t in core}
        if not hit:
            continue
        entry = {}
        for t, w in hit.items():
            n_units, d_in, d_out = w.shape
            key, k1 = jax.random.split(key)
            A = (jax.random.normal(k1, (n_units, d_in, rank), jnp.float32)
                 * 0.01).astype(w.dtype)
            B = jnp.zeros((n_units, rank, d_out), w.dtype)
            entry[t] = {"A": A, "B": B}
        out_units[bkey] = {"core": entry}
    lora["units"] = out_units
    return lora


def apply_lora(params, lora, alpha: float = 16.0):
    """Materialize W' = W + (α/r)·A@B for adapted leaves (pure)."""
    import copy
    new = dict(params)
    new_units = dict(params["units"])
    for bkey, entry in lora["units"].items():
        blk = dict(new_units[bkey])
        core = dict(blk["core"])
        for t, ab in entry["core"].items():
            r = ab["A"].shape[-1]
            delta = jnp.einsum("uir,uro->uio", ab["A"].astype(jnp.float32),
                               ab["B"].astype(jnp.float32)) * (alpha / r)
            core[t] = (core[t].astype(jnp.float32) + delta).astype(core[t].dtype)
        blk["core"] = core
        new_units[bkey] = blk
    new["units"] = new_units
    return new


def lora_param_count(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))
