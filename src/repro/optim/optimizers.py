"""First-order optimizers from scratch (for the FedAvg/FedLoRA baselines and
the FO comparison arm). Pytree-generic, functional, jit-safe.

ZO training (the paper's path) deliberately has NO optimizer state — that is
its memory story; see core/zo.py.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (or momentum buffer); None for plain SGD
    nu: Any          # second moment; None unless adam


# --- SGD -------------------------------------------------------------------

def sgd_update(params: Params, grads: Params, lr) -> Params:
    return jax.tree.map(lambda p, g: (p - lr * g.astype(jnp.float32)
                                      ).astype(p.dtype), params, grads)


# --- SGD + momentum ----------------------------------------------------------

def momentum_init(params: Params) -> OptState:
    mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), mu, None)


def momentum_update(params: Params, grads: Params, state: OptState, lr,
                    beta: float = 0.9) -> Tuple[Params, OptState]:
    mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                      state.mu, grads)
    new = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
    return new, OptState(state.step + 1, mu, None)


# --- AdamW -------------------------------------------------------------------

def adamw_init(params: Params) -> OptState:
    z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), z(), z())


def adamw_update(params: Params, grads: Params, state: OptState, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[Params, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)), state.nu, grads)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        step_ = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay
                      * p.astype(jnp.float32))
        return (p - step_).astype(p.dtype)
    return jax.tree.map(upd, params, mu, nu), OptState(step, mu, nu)


# --- factory -----------------------------------------------------------------

def make_optimizer(name: str):
    """Returns (init_fn, update_fn(params, grads, state, lr))."""
    if name == "sgd":
        return (lambda p: OptState(jnp.zeros((), jnp.int32), None, None),
                lambda p, g, s, lr: (sgd_update(p, g, lr),
                                     OptState(s.step + 1, None, None)))
    if name == "momentum":
        return momentum_init, momentum_update
    if name in ("adam", "adamw"):
        return adamw_init, adamw_update
    raise ValueError(name)
