from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    momentum_init, momentum_update, sgd_update,
                                    make_optimizer)
from repro.optim.schedules import constant, cosine, linear_warmup
from repro.optim.lora import apply_lora, init_lora, lora_param_count
