"""Learning-rate schedules (plain callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    return f


def cosine(lr: float, warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos
    return f
