"""Step builders: one (arch × shape × mesh) cell -> a jit-able step function
with ShapeDtypeStruct inputs and NamedShardings. Shared by the dry-run, the
roofline harness, and the real train/serve drivers.

  train_4k            -> train_step = one MU-SplitFed global round
  train_multi         -> build_train_multi_cell: C rounds fused in ONE
                         lax.scan dispatch (the engine's chunk body, perf
                         ladder v5) with donated params
  prefill_32k         -> prefill_step (prompt -> last logits + decode cache)
  decode_32k/long_500k-> serve_step (one new token against a seq_len cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import MeshConfig, SFLConfig, ShapeConfig, get_config
from repro.configs.base import ModelConfig
from repro.core.splitfed import mu_splitfed_round
from repro.models import init_cache, init_params, prefill, decode_step, untie_params
from repro.sharding import batch_pspec, cache_pspecs, param_pspecs, plan_for
from repro.sharding.specs import ctx_pspec
from repro.sharding.planner import Plan


class Cell(NamedTuple):
    """Everything needed to lower one (arch × shape × mesh) combination."""
    name: str
    fn: Callable                 # jit-able step
    args: tuple                  # ShapeDtypeStruct stand-ins
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    plan: Plan
    cfg: ModelConfig
    sfl: Optional[SFLConfig]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _param_setup(cfg: ModelConfig, mesh, plan: Plan, *, untied: bool):
    if untied:
        shapes = jax.eval_shape(
            lambda: untie_params(cfg, init_params(cfg, jax.random.PRNGKey(0))))
    else:
        shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(cfg, shapes, fsdp=plan.fsdp_axes,
                          axis_sizes=_axis_sizes(mesh))
    return shapes, _sharding_tree(mesh, pspecs)


def _batch_shapes_train(cfg: ModelConfig, M: int, b: int, S: int):
    batch = {"tokens": _sds((M, b, S), jnp.int32),
             "labels": _sds((M, b, S), jnp.int32)}
    if cfg.n_image_tokens > 0:
        batch["image_embeds"] = _sds((M, b, cfg.n_image_tokens, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((M, b, cfg.n_audio_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
    return batch


def _batch_shardings_train(cfg, mesh, multi_pod, plan):
    stacked = plan.client_mode == "parallel"
    if stacked:
        tok = batch_pspec("train", multi_pod, stacked_clients=True)
        ctx = P("data", "pod" if multi_pod else None, None, None)
    else:   # sequential: M is scanned; shard per-client batch over data (+SP)
        tok = P(None, "data", "pod" if multi_pod else None)
        ctx = P(None, "data", None, None)
    spec = {"tokens": tok, "labels": tok}
    if cfg.n_image_tokens > 0:
        spec["image_embeds"] = ctx
    if cfg.is_encoder_decoder:
        spec["frames"] = ctx
    return _sharding_tree(mesh, spec)


def default_sfl(cfg: ModelConfig, n_clients: int = 16, tau: int = 2) -> SFLConfig:
    return SFLConfig(n_clients=n_clients, tau=tau,
                     cut_units=cfg.default_cut_units)


def build_cell(arch: str, shape: ShapeConfig, mesh, *, smoke: bool = False,
               sfl: Optional[SFLConfig] = None, aggregation: str = "dense",
               replay: str = "auto", tau: int = 2,
               eval_loss: bool = False) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    multi_pod = "pod" in mesh.axis_names
    mesh_cfg = MeshConfig(shape=tuple(mesh.devices.shape),
                          axes=tuple(mesh.axis_names))
    plan = plan_for(cfg, shape, mesh_cfg, aggregation, replay)
    rep = NamedSharding(mesh, P())
    name = f"{arch}×{shape.name}×{'x'.join(map(str, mesh_cfg.shape))}"

    if shape.kind == "train":
        sfl = sfl or default_sfl(cfg, tau=tau)
        M = sfl.n_clients
        assert shape.global_batch % M == 0
        b = shape.global_batch // M
        pshapes, psh = _param_setup(cfg, mesh, plan, untied=True)
        batch = _batch_shapes_train(cfg, M, b, shape.seq_len)
        bsh = _batch_shardings_train(cfg, mesh, multi_pod, plan)
        mask = _sds((M,), jnp.float32)
        key = _sds((2,), jnp.uint32)

        def fn(params, batches, active, k):
            new_params, metrics = mu_splitfed_round(
                cfg, sfl, params, batches, active, k,
                client_mode=plan.client_mode, aggregation=plan.aggregation,
                replay=plan.replay, eval_loss=eval_loss)
            return new_params, metrics.loss

        return Cell(name, fn, (pshapes, batch, mask, key),
                    (psh, bsh, rep, rep), (psh, rep), (0,), plan, cfg, sfl)

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        pshapes, psh = _param_setup(cfg, mesh, plan, untied=False)
        batch = {"tokens": _sds((B, S), jnp.int32)}
        bspec = {"tokens": batch_pspec("serve", multi_pod,
                                       stacked_clients=False)}
        if cfg.n_image_tokens > 0:
            batch["image_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
            bspec["image_embeds"] = ctx_pspec(multi_pod, stacked_clients=False)
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((B, cfg.n_audio_frames, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
            bspec["frames"] = ctx_pspec(multi_pod, stacked_clients=False)
        bsh = _sharding_tree(mesh, bspec)
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
        csh = _sharding_tree(mesh, cache_pspecs(cfg, cache_shapes, B, multi_pod,
                                                axis_sizes=_axis_sizes(mesh)))

        def fn(params, b_):
            return prefill(cfg, params, b_, cache_len=S)

        return Cell(name, fn, (pshapes, batch), (psh, bsh),
                    (rep, csh), (), plan, cfg, None)

    # decode (decode_32k / long_500k): one token against a seq_len cache
    B, S = shape.global_batch, shape.seq_len
    pshapes, psh = _param_setup(cfg, mesh, plan, untied=False)
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
    csh = _sharding_tree(mesh, cache_pspecs(cfg, cache_shapes, B, multi_pod,
                                            axis_sizes=_axis_sizes(mesh)))
    token = _sds((B, 1), jnp.int32)
    tsh = _sharding_tree(mesh, P(("pod", "data") if multi_pod and B % 32 == 0
                                 else ("data" if B % 16 == 0 else None), None))
    pos = _sds((), jnp.int32)

    def fn(params, tok, cache, p_):
        return decode_step(cfg, params, tok, cache, p_)

    return Cell(name, fn, (pshapes, token, cache_shapes, pos),
                (psh, tsh, csh, rep), (rep, csh), (2,), plan, cfg, None)


def build_train_multi_cell(arch: str, shape: ShapeConfig, mesh, *,
                           rounds_per_chunk: int = 4, smoke: bool = False,
                           sfl: Optional[SFLConfig] = None,
                           aggregation: str = "dense", replay: str = "auto",
                           tau: int = 2, algorithm: str = "mu_splitfed",
                           eval_loss: bool = False) -> Cell:
    """The fused multi-round train cell (perf ladder v5): C global rounds
    execute as ONE jit dispatch — a lax.scan over the engine's round body
    with params donated across the whole chunk. Batches/masks/keys gain a
    leading (C,) round dim and are scanned as data; the per-round stacked
    loss comes back for the chunk at once (one host sync per C rounds).
    """
    from repro.core import engine as eng
    assert shape.kind == "train", "train_multi only lowers train shapes"
    assert algorithm in ("mu_splitfed", "vanilla"), (
        "the perf cell scans stateless algorithms; stateful ones (gas, "
        "fedlora) carry their state through engine.run_rounds instead")
    cfg = get_config(arch, smoke=smoke)
    multi_pod = "pod" in mesh.axis_names
    mesh_cfg = MeshConfig(shape=tuple(mesh.devices.shape),
                          axes=tuple(mesh.axis_names))
    plan = plan_for(cfg, shape, mesh_cfg, aggregation, replay)
    rep = NamedSharding(mesh, P())
    sfl = sfl or default_sfl(cfg, tau=tau)
    M = sfl.n_clients
    assert shape.global_batch % M == 0
    b = shape.global_batch // M
    C = rounds_per_chunk
    name = (f"{arch}×{shape.name}×{'x'.join(map(str, mesh_cfg.shape))}"
            f"×chunk{C}")

    pshapes, psh = _param_setup(cfg, mesh, plan, untied=True)
    batch1 = _batch_shapes_train(cfg, M, b, shape.seq_len)
    batch = jax.tree.map(lambda s: _sds((C,) + s.shape, s.dtype), batch1)
    bsh1 = _batch_shardings_train(cfg, mesh, multi_pod, plan)
    bsh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*((None,) + tuple(s.spec)))), bsh1)
    masks = _sds((C, M), jnp.float32)
    keys = _sds((C, 2), jnp.uint32)

    algo = eng.get_algorithm(algorithm, client_mode=plan.client_mode,
                             aggregation=plan.aggregation, replay=plan.replay,
                             eval_loss=eval_loss)
    chunk = eng.make_chunk_fn(algo, cfg, sfl)

    def fn(params, batches, m, k):
        params, _, mets = chunk(params, (), batches, m, k)
        return params, mets["loss"]

    return Cell(name, fn, (pshapes, batch, masks, keys),
                (psh, bsh, rep, rep), (psh, rep), (0,), plan, cfg, sfl)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    return jitted.lower(*cell.args)
