"""Training driver: MU-SplitFed (or a baseline) end to end on real data.

Runs on whatever devices exist: CPU smoke configs locally, the production
mesh on a pod. Fault tolerance built in: atomic async checkpoints every
--ckpt-every rounds, automatic resume from the latest checkpoint (data
order is stateless in the round index, so restarts are exact), straggler
simulation + deadline drop + τ re-planning from observed delays.

Example (CPU):
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --rounds 20 --tau 2 --clients 4 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import Checkpointer
from repro.configs import SFLConfig, get_config
from repro.core import straggler as strag
from repro.core.splitfed import mu_splitfed_round
from repro.core.baselines import (gas_init_state, gas_round,
                                  vanilla_splitfed_round)
from repro.data import FederatedLoader, SyntheticLM, dirichlet_partition
from repro.models import init_params, untie_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--algorithm", default="mu_splitfed",
                    choices=["mu_splitfed", "vanilla", "gas"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="per-client batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--cut", type=int, default=0, help="0 = arch default")
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--straggler-scale", type=float, default=0.0)
    ap.add_argument("--deadline", type=float, default=0.0)
    ap.add_argument("--t-server", type=float, default=0.1,
                    help="simulated server step time (s) for the wall-clock "
                         "model")
    ap.add_argument("--t-gen", type=float, default=0.0,
                    help="GAS activation-generation overhead (s) per round")
    ap.add_argument("--aggregation", default="dense",
                    choices=["dense", "seed_replay"])
    ap.add_argument("--client-mode", default="parallel",
                    choices=["parallel", "sequential"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr-server", type=float, default=1e-3)
    ap.add_argument("--lr-client", type=float, default=5e-4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    sfl = SFLConfig(n_clients=args.clients, tau=args.tau,
                    cut_units=args.cut or cfg.default_cut_units,
                    lr_server=args.lr_server, lr_client=args.lr_client,
                    participation=args.participation)
    key = jax.random.PRNGKey(args.seed)
    params = untie_params(cfg, init_params(cfg, key))

    # data: synthetic LM, Dirichlet-partitioned across clients
    n_samples = 4096
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     seed=args.seed)
    pseudo_labels = np.arange(n_samples) % 10
    parts = dirichlet_partition(pseudo_labels, args.clients, alpha=0.5,
                                seed=args.seed)
    loader = FederatedLoader(ds, parts, args.batch, seed=args.seed)

    # fault tolerance: resume if a checkpoint exists
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_round = 0
    if ck is not None:
        from repro.ckpt import latest_step
        step = latest_step(args.ckpt_dir)
        if step is not None:
            params, meta = ck.restore(params, step)
            start_round = meta["step"] + 1
            print(f"[resume] from round {start_round}")

    rng = np.random.default_rng(args.seed)
    delay_model = strag.DelayModel(base=1.0, scale=args.straggler_scale)
    wall = strag.WallClock()

    round_fn = jax.jit(lambda p, b, m, k: mu_splitfed_round(
        cfg, sfl, p, b, m, k, client_mode=args.client_mode,
        aggregation=args.aggregation))
    if args.algorithm == "vanilla":
        round_fn = jax.jit(lambda p, b, m, k: vanilla_splitfed_round(
            cfg, sfl, p, b, m, k, client_mode=args.client_mode,
            aggregation=args.aggregation))
    gas_state = None

    for r in range(start_round, args.rounds):
        batch = loader.round_batch(r)
        # straggler system model: delays -> participation/deadline masks
        delays = delay_model.sample(rng, args.clients, 1)[0] \
            if args.straggler_scale > 0 else np.ones(args.clients)
        mask = strag.participation_mask(rng, args.clients,
                                        args.participation)
        mask = mask * strag.deadline_mask(delays, args.deadline)
        rkey = jax.random.fold_in(key, r)
        t0 = time.time()
        if args.algorithm == "gas":
            if gas_state is None:
                gas_state = gas_init_state(cfg, sfl, params, batch)
            params, gas_state, metrics = gas_round(
                cfg, sfl, params, gas_state, batch,
                jnp.asarray(mask), rkey, aggregation=args.aggregation)
        else:
            params, metrics = round_fn(params, batch, jnp.asarray(mask),
                                       rkey)
        loss = float(jnp.sum(metrics.loss * mask) / max(mask.sum(), 1))
        # per-algorithm wall-clock model (straggler.py): each algorithm has
        # its own overlap structure, so each must be charged its own time
        if args.algorithm == "gas":
            dt = strag.round_time_gas(delays, mask, t_server=args.t_server,
                                      t_gen=args.t_gen)
        elif args.algorithm == "vanilla":
            dt = strag.round_time_vanilla(delays, mask,
                                          t_server=args.t_server)
        else:
            dt = strag.round_time_mu_splitfed(delays, mask,
                                              t_server=args.t_server,
                                              tau=sfl.tau)
        sim_t = wall.tick(dt)
        print(f"round {r:4d}  loss {loss:.4f}  active {int(mask.sum())}/"
              f"{args.clients}  wall {time.time()-t0:.1f}s  sim_t {sim_t:.1f}")
        if ck is not None and (r + 1) % args.ckpt_every == 0:
            ck.save(r, params, metadata={"loss": loss})
    if ck is not None:
        ck.save(args.rounds - 1, params, block=True)
    return params


if __name__ == "__main__":
    main()
