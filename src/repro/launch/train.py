"""Training driver: any registered algorithm end to end on real data,
through the unified engine (core/engine.py).

Runs on whatever devices exist: CPU smoke configs locally, the production
mesh on a pod. The per-round Python loop is gone — rounds execute as a
chunked, jit'd lax.scan with donated params/state; straggler delays,
participation/deadline masks, and per-round keys are precomputed host-side
by straggler.make_schedule and scanned as data. Fault tolerance built in:
atomic async checkpoints at chunk boundaries every --ckpt-every rounds,
automatic resume from the latest checkpoint (data order and the schedule
are stateless in the round index, so restarts are exact).

Example (CPU):
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --rounds 20 --tau 2 --clients 4 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import jax
import numpy as np

import repro.obs as obs
from repro.ckpt import Checkpointer
from repro.configs import SFLConfig, get_config
from repro.core import engine, events
from repro.core import straggler as strag
from repro.data import FederatedLoader, SyntheticLM, dirichlet_partition
from repro.models import init_params, untie_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--algorithm", default="mu_splitfed",
                    choices=sorted(engine.ALGORITHMS))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="per-client batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--cut", type=int, default=0, help="0 = arch default")
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--straggler-scale", type=float, default=0.0)
    ap.add_argument("--deadline", type=float, default=0.0)
    ap.add_argument("--population", default="",
                    help="heterogeneous fleet spec, e.g. "
                         "'tiered:4x1.0,12x0.2' — per cohort "
                         "<n>x<speed>[@part][~p_drop/p_recover][%%comm_scale]"
                         " (~~p/p: one SHARED chain per cohort — tier-wide "
                         "outages); overrides --clients/--participation (the "
                         "deprecated single-cohort shorthand); "
                         "--straggler-scale becomes the shared jitter")
    ap.add_argument("--async", dest="run_async", action="store_true",
                    help="event-driven semi-async execution (core/events.py)"
                         ": commit a server version as soon as --quorum "
                         "contributions arrive; late arrivals fold into a "
                         "later commit, discounted by --staleness-discount "
                         "per missed commit. Implies "
                         "--algorithm async_mu_splitfed")
    ap.add_argument("--quorum", type=int, default=0,
                    help="semi-async commit quorum K (0 = wait for all "
                         "pending contributions — the synchronous barrier)")
    ap.add_argument("--staleness-discount", type=float, default=1.0,
                    help="weight base for stale contributions: a record "
                         "applied s commits after its fetch weighs "
                         "discount**s before per-commit normalization")
    ap.add_argument("--timeline", default="dense",
                    choices=["dense", "sparse"],
                    help="async timeline backend: 'dense' precompiles "
                         "(V, M) rows (small-M reference); 'sparse' "
                         "streams (chunk, k_max) commit batches over an "
                         "arrival-slot ring store — pick it for large "
                         "fleets (quorum K << M)")
    ap.add_argument("--k-max", type=int, default=0,
                    help="sparse timeline: per-version commit-batch width "
                         "(0 = auto: 4x quorum, floor 16, capped at M)")
    ap.add_argument("--ring-capacity", type=int, default=0,
                    help="sparse timeline: in-flight record slots (0 = "
                         "auto: an 8-batch staleness window, capped at M)")
    ap.add_argument("--loader", default="fleet",
                    choices=["fleet", "subset"],
                    help="sparse data staging: 'fleet' gathers each "
                         "version's rows from a fleet-width (M, ...) stack; "
                         "'subset' materializes only the <= k_max clients "
                         "that start each version (O(K) host staging, "
                         "bit-exact vs the gather) — requires --timeline "
                         "sparse")
    ap.add_argument("--fleet-shard", type=int, default=0,
                    help="shard the arrival-slot ring store, fleet system "
                         "vectors, and staged commit batches over N devices "
                         "on a ('data',) mesh (launch/fleet.py; 0 = off, "
                         "replicated). Requires --async --timeline sparse "
                         "and ring/k_max geometry divisible by N")
    ap.add_argument("--faults", default="",
                    help="fault-injection plan (core/faults.py), e.g. "
                         "'crash=0.1,loss=0.05,dup=0.02,corrupt=0.01,"
                         "kill=40' — crash-after-fetch / delivery-loss / "
                         "duplication / corruption rates per dispatch, "
                         "'key@cohort=rate' per-cohort overrides, "
                         "'backoff=s' crash re-dispatch base, 'kill=R' "
                         "SIGKILLs the process after the chunk containing "
                         "round R (checkpoint-resume exercise). Event "
                         "rates require --async")
    ap.add_argument("--quorum-timeout", type=float, default=0.0,
                    help="graceful degradation: commit with however many "
                         "contributions arrived once the quorum has "
                         "waited this long (weights renormalized; 0 = "
                         "wait forever). Requires --async")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="retransmissions per lost delivery before the "
                         "contribution is dropped")
    ap.add_argument("--adaptive-quorum", action="store_true",
                    help="shrink/grow the commit quorum K from the "
                         "observed delivery rate (engine.AdaptiveQuorum; "
                         "--quorum is K0, the cap). Requires --async and "
                         "a --quorum > 0")
    ap.add_argument("--adaptive-tau", action="store_true",
                    help="re-plan tau at chunk boundaries from the observed "
                         "straggler gap (engine.AdaptiveTau; --tau is the "
                         "starting point)")
    ap.add_argument("--tau-max", type=int, default=64,
                    help="cap for --adaptive-tau's planner")
    ap.add_argument("--tau-source", default="sim",
                    choices=["sim", "measured"],
                    help="clock --adaptive-tau observes the straggler gap "
                         "on: 'sim' reads the schedule's simulated rows "
                         "(historical behaviour); 'measured' reads the "
                         "measured-clock RoundTelemetry records from the "
                         "engine's sink (real per-chunk wall time)")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach a TelemetrySink to the engine (sim + "
                         "measured producers at chunk boundaries) and print "
                         "the telemetry/metrics summary at run end")
    ap.add_argument("--trace-out", default="",
                    help="write the span trace here at run end: .json = "
                         "Chrome trace-event format (chrome://tracing / "
                         "perfetto), .jsonl = one span per line")
    ap.add_argument("--log-jsonl", default="",
                    help="structured JSONL run log: per-round rows plus "
                         "per-chunk RoundTelemetry summaries; resume "
                         "truncates re-run rounds so nothing duplicates")
    ap.add_argument("--log-every", type=int, default=1,
                    help="log every Nth round row to --log-jsonl (chunk "
                         "rows always log)")
    ap.add_argument("--t-server", type=float, default=0.1,
                    help="simulated server step time (s) for the wall-clock "
                         "model")
    ap.add_argument("--t-gen", type=float, default=0.0,
                    help="GAS activation-generation overhead (s) per round")
    ap.add_argument("--t-comm", type=float, default=0.0,
                    help="simulated per-round communication time (s), "
                         "charged by every algorithm's wall-clock model")
    ap.add_argument("--aggregation", default=None,
                    choices=["dense", "seed_replay"],
                    help="server aggregation (default dense; --async "
                         "requires seed_replay — the record store is the "
                         "replay wire format)")
    ap.add_argument("--client-mode", default="parallel",
                    choices=["parallel", "sequential"])
    ap.add_argument("--loop", default=None, choices=["scan", "python"],
                    help="fused multi-round scan (default) or the legacy "
                         "one-dispatch-per-round loop; incompatible with "
                         "--async (which runs the event-driven mode)")
    ap.add_argument("--chunk-size", type=int, default=8,
                    help="rounds fused per scan dispatch")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr-server", type=float, default=1e-3)
    ap.add_argument("--lr-client", type=float, default=5e-4)
    args = ap.parse_args(argv)

    if args.run_async:
        if args.loop is not None:
            raise SystemExit("--async and --loop are mutually exclusive: "
                             "--async runs the event-driven mode")
        if args.algorithm == "mu_splitfed":
            args.algorithm = "async_mu_splitfed"
        elif args.algorithm != "async_mu_splitfed":
            raise SystemExit(f"--async supports async_mu_splitfed, "
                             f"not {args.algorithm}")
        if args.aggregation == "dense":
            raise SystemExit("--async requires --aggregation seed_replay: "
                             "the in-flight record store is the seed-replay "
                             "wire format")
        args.aggregation = "seed_replay"
        args.loop = "async"
    else:
        if args.quorum or args.staleness_discount != 1.0:
            raise SystemExit("--quorum/--staleness-discount only take "
                             "effect under --async (the synchronous modes "
                             "never read them)")
        if args.timeline != "dense":
            ap.error("--timeline sparse is the semi-async streaming "
                     "backend; it requires --async")
        if args.loop is None:
            args.loop = "scan"
        if args.aggregation is None:
            args.aggregation = "dense"
    fault_plan = None
    if args.faults:
        from repro.core.faults import parse_faults
        try:
            fault_plan = parse_faults(args.faults)
        except ValueError as e:
            ap.error(str(e))
    if not args.run_async:
        if fault_plan is not None and fault_plan.any():
            ap.error("--faults event rates perturb the semi-async event "
                     "stream; they require --async (kill=R alone works "
                     "in any mode)")
        if args.quorum_timeout or args.adaptive_quorum:
            ap.error("--quorum-timeout/--adaptive-quorum are semi-async "
                     "degradation knobs; they require --async")
    if args.quorum_timeout < 0:
        ap.error(f"--quorum-timeout must be >= 0: got "
                 f"{args.quorum_timeout}")
    if args.max_retries < 0:
        ap.error(f"--max-retries must be >= 0: got {args.max_retries}")
    if args.adaptive_quorum and args.quorum <= 0:
        ap.error("--adaptive-quorum plans within [1, K0]; pass a finite "
                 "initial --quorum > 0")
    if args.adaptive_quorum and args.adaptive_tau:
        ap.error("--adaptive-tau and --adaptive-quorum are separate "
                 "controllers; the engine runs one controller per run")
    if args.loader == "subset" and args.timeline != "sparse":
        ap.error("--loader subset is the sparse O(K) staging path; it "
                 "requires --async --timeline sparse")
    if args.fleet_shard < 0:
        ap.error(f"--fleet-shard must be >= 0 (0 = off): got "
                 f"{args.fleet_shard}")
    if args.fleet_shard and args.timeline != "sparse":
        ap.error("--fleet-shard places the sparse ring store; it requires "
                 "--async --timeline sparse")

    cfg = get_config(args.arch, smoke=args.smoke)
    # the client fleet: an explicit heterogeneous population, or the
    # deprecated scalar shorthand resolved to a single cohort
    population = (strag.parse_population(
        args.population, straggler_scale=args.straggler_scale)
        if args.population else None)
    n_clients = population.n_clients if population else args.clients
    # validate the semi-async policy knobs against the RESOLVED fleet size
    # (an oversized quorum used to be silently clamped inside the DES)
    if args.quorum < 0 or args.quorum > n_clients:
        ap.error(f"--quorum must be in [0, n_clients]: got {args.quorum} "
                 f"with n_clients={n_clients} (0 = wait for all pending)")
    if not 0.0 <= args.staleness_discount <= 1.0:
        ap.error(f"--staleness-discount must be in [0.0, 1.0]: got "
                 f"{args.staleness_discount} (weight base per missed "
                 f"commit)")
    if args.k_max < 0 or args.ring_capacity < 0:
        ap.error("--k-max/--ring-capacity must be >= 0 (0 = auto)")
    if population is not None:
        print(f"population: {population.describe()}  (M={n_clients})")
    sfl = SFLConfig(n_clients=n_clients, tau=args.tau,
                    cut_units=args.cut or cfg.default_cut_units,
                    lr_server=args.lr_server, lr_client=args.lr_client,
                    participation=args.participation,
                    straggler_rate=args.straggler_scale,
                    deadline=args.deadline, population=population,
                    quorum=args.quorum,
                    staleness_discount=args.staleness_discount,
                    timeline=args.timeline, k_max=args.k_max,
                    ring_capacity=args.ring_capacity,
                    faults=fault_plan, quorum_timeout=args.quorum_timeout,
                    max_retries=args.max_retries)
    if fault_plan is not None:
        print(f"faults: {fault_plan.describe()}"
              + (f"  quorum_timeout={args.quorum_timeout:g}"
                 if args.quorum_timeout else "")
              + f"  max_retries={args.max_retries}")
    # resolve the mesh placement BEFORE any device work: geometry errors
    # (ring/k_max not divisible by the 'data' axis, too few devices) are
    # launch-time misconfigurations, not mid-run surprises
    placement = None
    if args.fleet_shard:
        if args.fleet_shard > len(jax.devices()):
            ap.error(f"--fleet-shard {args.fleet_shard} exceeds the "
                     f"{len(jax.devices())} available devices")
        from repro.launch.fleet import build_fleet_placement
        try:
            placement = build_fleet_placement(
                sfl, data_devices=args.fleet_shard)
        except ValueError as e:
            ap.error(str(e))
        print(f"fleet placement: ring store sharded over "
              f"{args.fleet_shard} devices ({placement.plan})")
    key = jax.random.PRNGKey(args.seed)
    params = untie_params(cfg, init_params(cfg, key))

    # data: synthetic LM, Dirichlet-partitioned across clients
    n_samples = 4096
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     seed=args.seed)
    pseudo_labels = np.arange(n_samples) % 10
    parts = dirichlet_partition(pseudo_labels, n_clients, alpha=0.5,
                                seed=args.seed)
    loader = FederatedLoader(ds, parts, args.batch, seed=args.seed)

    algo = engine.get_algorithm(args.algorithm, **(
        {"client_mode": args.client_mode, "aggregation": args.aggregation}
        if args.algorithm in ("mu_splitfed", "vanilla", "async_mu_splitfed")
        else {"aggregation": args.aggregation}
        if args.algorithm == "gas" else {}))
    if args.run_async:
        print(f"semi-async: quorum {args.quorum or 'all'} of {n_clients}, "
              f"staleness discount {args.staleness_discount}, "
              f"timeline {args.timeline}" + (
                  " (k_max {}, ring {})".format(
                      *events.resolve_store_geometry(sfl))
                  if args.timeline == "sparse" else ""))

    if args.log_every < 1:
        ap.error(f"--log-every must be >= 1: got {args.log_every}")
    if args.tau_source == "measured" and not args.adaptive_tau:
        ap.error("--tau-source measured configures --adaptive-tau's clock; "
                 "pass --adaptive-tau")
    controller = (engine.AdaptiveTau(tau_max=args.tau_max,
                                     source=args.tau_source)
                  if args.adaptive_tau
                  else engine.AdaptiveQuorum()
                  if args.adaptive_quorum else None)
    # the observability layer: sink (engine producers -> controller/log),
    # tracer (span records over the hot path), metrics (running totals).
    # AdaptiveQuorum observes fault counters through the sink, so it
    # forces one on.
    sink = (obs.TelemetrySink()
            if (args.telemetry or args.log_jsonl or args.adaptive_quorum
                or args.tau_source == "measured") else None)
    tracer = None
    if args.trace_out:
        tracer = obs.SpanTracer()
        obs.install(tracer)
    registry = obs.get_registry()

    # fault tolerance: resume if a checkpoint exists (engine state —
    # e.g. the GAS activation buffer — rides along in the bundle, and
    # controller decisions/EMA state replay from the metadata)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_round, state = 0, None
    tau_history, quorum_history = None, None
    if ck is not None:
        from repro.ckpt import latest_good_step, read_meta
        if latest_good_step(args.ckpt_dir) is not None:
            # replay controller overrides BEFORE restoring: stateful
            # templates (e.g. the async record store's τ axis) are built
            # from the adapted config. latest_good_step walks past any
            # checkpoint that fails its content checksum — a crash mid-
            # save resumes from the last good chunk boundary.
            sfl = engine.apply_resume_overrides(
                sfl, read_meta(args.ckpt_dir), controller)
            params, state, meta = engine.restore_run(
                ck, algo, cfg, sfl, params, loader.round_batch)
            start_round = meta["step"] + 1
            # async controller runs: recompile the timeline prefix with
            # the per-version τ / quorum that actually executed
            tau_history = meta["metadata"].get("tau_per_version")
            quorum_history = meta["metadata"].get("quorum_per_version")
            print(f"[resume] from round {start_round} (tau={sfl.tau})")

    # the whole system model — per-cohort delays, availability chains,
    # participation, deadline drops — as precomputed (R, M) data the
    # engine scans
    sched = strag.make_schedule(
        args.seed, args.rounds, population=strag.ClientPopulation.resolve(sfl),
        deadline=args.deadline,
        t_server=args.t_server, t_gen=args.t_gen, t_comm=args.t_comm)

    runlog = (obs.RunLog(args.log_jsonl, resume_round=start_round,
                         log_every=args.log_every)
              if args.log_jsonl else None)

    wall = strag.WallClock()
    t0 = time.time()

    def on_chunk(info, p, s):
        for i, r in enumerate(range(info.start, info.stop)):
            sim_t = wall.tick(info.round_times[i])
            print(f"round {r:4d}  loss {info.round_loss[i]:.4f}  active "
                  f"{int((info.masks[i] > 0).sum())}/{n_clients}  "
                  f"wall {time.time()-t0:.1f}s  sim_t {sim_t:.1f}")
            if runlog is not None:
                runlog.round(r, loss=float(info.round_loss[i]),
                             active=int((info.masks[i] > 0).sum()),
                             sim_t=float(sim_t),
                             wall_s=round(time.time() - t0, 3))
        if sink is not None:
            registry.counter("train.rounds").inc(info.stop - info.start)
            registry.counter("train.chunks").inc()
            registry.gauge("train.last_loss").set(float(info.round_loss[-1]))
            h = registry.histogram("train.sim_round_seconds")
            for dt in info.round_times:
                h.observe(float(dt))
            meas = sink.latest("measured")
            if meas is not None and meas.stop == info.stop:
                registry.histogram("train.chunk_dispatch_seconds").observe(
                    meas.dispatch_seconds)
                registry.counter("train.staging_bytes").inc(
                    meas.staging_bytes)
        if sink is not None:
            # degradation accounting: mirror the chunk's simulator fault
            # counters into the metrics registry so /stats surfaces
            # contribution loss without replaying the telemetry ring
            for rec in sink.window(info.start, info.stop, "sim"):
                for f in ("started", "evicted", "crashed", "lost",
                          "corrupt", "dups", "retries", "timeouts"):
                    n = getattr(rec, f)
                    if n:
                        registry.counter(f"train.faults.{f}").inc(n)
        if runlog is not None:
            runlog.chunk(info.start, info.stop,
                         telemetry=(sink.window(info.start, info.stop)
                                    if sink is not None else ()))
        if (fault_plan is not None
                and info.start <= fault_plan.kill_round < info.stop):
            # the host-kill schedule: SIGKILL (no cleanup, no atexit —
            # the real failure mode) right after the chunk containing
            # kill_round flushed and BEFORE its checkpoint lands; resume
            # restarts from the previous good boundary
            print(f"[faults] kill={fault_plan.kill_round}: SIGKILL after "
                  f"chunk [{info.start}, {info.stop})", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)

    if placement is not None and state is None:
        # pre-place the initial ring store so the scan's donated state
        # carries the 'data'-axis layout from version 0
        state = placement.place_store(events.init_store(sfl))
    result = engine.run_rounds(
        algo, cfg, sfl, params, loader.round_batch, sched, key,
        rounds=args.rounds, start_round=start_round, state=state,
        chunk_size=args.chunk_size, mode=args.loop, checkpointer=ck,
        ckpt_every=args.ckpt_every, chunk_callback=on_chunk,
        controller=controller, tau_history=tau_history,
        quorum_history=quorum_history,
        batch_subset_fn=(loader.subset_batch
                         if args.loader == "subset" else None),
        batch_put=placement.batch_put if placement is not None else None,
        telemetry=sink)
    if controller is not None and controller.trace:
        vals = [t for _, t in controller.trace]
        if args.adaptive_quorum:
            print(f"adaptive quorum: K0 {args.quorum} -> final {vals[-1]} "
                  f"(decisions: {vals})")
        else:
            print(f"adaptive tau ({args.tau_source}): start {args.tau} -> "
                  f"final {vals[-1]} (decisions: {vals})")
    if runlog is not None:
        runlog.close()
        print(f"run log: {args.log_jsonl}")
    if tracer is not None:
        n_spans = (tracer.export_jsonl(args.trace_out)
                   if args.trace_out.endswith(".jsonl")
                   else tracer.export_chrome(args.trace_out))
        print(f"trace: {n_spans} spans -> {args.trace_out}")
    if args.telemetry:
        import json
        print("telemetry summary:")
        print(json.dumps(sink.summary(), indent=2, sort_keys=True))
        print("metrics:")
        print(json.dumps(registry.snapshot(), indent=2, sort_keys=True))
    return result.params


if __name__ == "__main__":
    main()
