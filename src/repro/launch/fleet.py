"""Fleet-scale device placement for the sparse semi-async path.

PR 5 built the 'data'-axis layout rules (sharding.specs.population_pspecs
/ event_store_pspecs) but nothing consumed them: the engine ran with the
ring store and staged batches replicated. This module is the launch path
that closes that gap — it resolves the store geometry against a mesh
(sharding.planner.plan_event_store), materializes NamedShardings for

  * the arrival-slot ring store (events.init_store leaves, slot dim),
  * the population's (M,) client vectors (cohort id, delay/comm scales),
  * the engine's staged (C, K, ...) sparse batch chunks (K dim),

and hands the engine a pre-placed initial store (``state=``) plus a
``batch_put`` hook so the 6-tuple scan runs with the store sharded over
'data' instead of replicated. All specs are divisibility-guarded: a dim
that doesn't divide the axis replicates, and the scan's gather/scatter
over slot indices lowers to GSPMD collectives either way — placement is a
layout hint, never a semantics change.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, SFLConfig
from repro.core import events
from repro.obs.trace import span
from repro.core.population import ClientPopulation
from repro.sharding.planner import EventStorePlan, plan_event_store
from repro.sharding.specs import (_guard, event_store_pspecs,
                                  population_pspecs)

__all__ = ["FleetPlacement", "build_fleet_placement"]


@dataclasses.dataclass(frozen=True)
class FleetPlacement:
    """Resolved mesh + shardings for one sparse-async run."""
    mesh: jax.sharding.Mesh
    plan: EventStorePlan
    k_max: int
    axis_sizes: Dict[str, int]

    def place_store(self, store: Dict[str, jax.Array]) -> Dict[str, Any]:
        """device_put the ring store with its slot dim over 'data'."""
        with span("fleet.place_store", leaves=len(store)):
            specs = event_store_pspecs(store, slot_axis="data",
                                       axis_sizes=self.axis_sizes)
            return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                    for k, v in store.items()}

    def place_vectors(self, population: ClientPopulation
                      ) -> Dict[str, jax.Array]:
        """device_put the fleet's (M,) system vectors over 'data'."""
        with span("fleet.place_vectors", clients=population.n_clients):
            vecs = population.client_vectors()
            specs = population_pspecs(vecs, axis_sizes=self.axis_sizes)
            return {k: jax.device_put(np.asarray(v),
                                      NamedSharding(self.mesh, specs[k]))
                    for k, v in vecs.items()}

    def batch_put(self, tree: Any) -> Any:
        """Place a staged (C, K, ...) sparse chunk: the scan (C) dim
        replicates, the K batch-row dim shards over 'data' when it
        divides. Engine hook: run_rounds(..., batch_put=placement
        .batch_put)."""
        def put(x):
            if np.ndim(x) < 2:
                return x
            ax = _guard(np.shape(x)[1], "data", self.axis_sizes)
            spec = P(None, ax, *((None,) * (np.ndim(x) - 2)))
            return jax.device_put(x, NamedSharding(self.mesh, spec))
        with span("fleet.batch_put"):
            return jax.tree.map(put, tree)


def build_fleet_placement(sfl: SFLConfig, *,
                          mesh: Optional[jax.sharding.Mesh] = None,
                          data_devices: int = 0) -> FleetPlacement:
    """Resolve the sparse store geometry against a mesh.

    ``mesh`` supplies an existing mesh with a 'data' axis; otherwise a
    1-D ('data',) mesh is built over ``data_devices`` devices (0 = all
    local). Raises ValueError when the resolved ring capacity or k_max
    does not divide the 'data' axis — callers that want parse-time
    validation (launch.train) call this before any device work.
    """
    if sfl.timeline != "sparse":
        raise ValueError("build_fleet_placement places the sparse ring "
                         f"store; sfl.timeline is {sfl.timeline!r}")
    if mesh is None:
        n = data_devices or len(jax.devices())
        if n > len(jax.devices()):
            raise ValueError(f"data_devices={n} exceeds the "
                             f"{len(jax.devices())} available devices")
        mesh = jax.make_mesh((n,), ("data",))
    if "data" not in mesh.axis_names:
        raise ValueError(f"fleet placement needs a 'data' mesh axis; got "
                         f"{mesh.axis_names}")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    k_max, capacity = events.resolve_store_geometry(sfl)
    data = axis_sizes.get("data", 1)
    if capacity % data:
        raise ValueError(
            f"ring capacity {capacity} does not divide the 'data' axis "
            f"({data} devices) — pass --ring-capacity a multiple of {data}")
    if k_max % data:
        raise ValueError(
            f"k_max {k_max} does not divide the 'data' axis ({data} "
            f"devices) — pass --k-max a multiple of {data}")
    plan = plan_event_store(
        capacity, sfl.n_clients,
        MeshConfig(shape=tuple(mesh.devices.shape),
                   axes=tuple(mesh.axis_names)),
        tau=sfl.tau, n_pert=sfl.n_perturbations)
    return FleetPlacement(mesh=mesh, plan=plan, k_max=k_max,
                          axis_sizes=axis_sizes)
