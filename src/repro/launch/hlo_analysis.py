"""Post-optimization HLO analysis with call-graph expansion.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE — a
lax.scan over 72 layers (or M clients × τ ZO steps) under-reports FLOPs and
bytes by the trip count, and collective bytes are not reported at all. This
parser reconstructs step-level totals from the post-optimization HLO text.

Two passes:
  1. symbol table: instruction name -> result shape(s) (post-opt HLO
     references operands by %name without inline types);
  2. per-computation stats:
       collectives : all-gather / all-reduce / reduce-scatter / all-to-all /
                     collective-permute (+ async -start), operand bytes;
       dot FLOPs   : 2 · |result| · |lhs contracting dims|  (matmuls dominate
                     transformer compute; elementwise FLOPs excluded);
       HBM bytes   : result + operand bytes of top-level ops. Fusion
                     interiors are opaque — matching XLA's semantics that
                     only fusion boundaries touch HBM.

Expansion: ENTRY totals; while bodies × trip count (lax.scan lowers its
bound to an ``s32[] constant(N)`` compare in the condition computation);
fusions contribute their interior dot FLOPs ×1; call/conditional ×1.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_RESULT_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z][\w\-]*)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                   "constant", "while", "call", "conditional", "iota",
                   "after-all", "copy-start", "copy-done", "partition-id",
                   "replica-id", "broadcast", "reshape", "transpose"}
# in-place update ops: traffic = the update slice, not the full buffer
_INPLACE_OPS = {"dynamic-update-slice", "scatter", "select-and-scatter"}


def _shapes_bytes(shapes: List[Tuple[str, str]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims_of(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


class CompStats:
    __slots__ = ("coll", "flops", "bytes", "whiles", "calls")

    def __init__(self):
        self.coll: Dict[str, float] = defaultdict(float)
        self.flops = 0.0
        self.bytes = 0.0
        self.whiles: List[Tuple[str, str]] = []
        self.calls: List[str] = []


def parse_hlo(text: str):
    lines = text.splitlines()
    # ---- pass 1: symbol table (name -> list of shapes) ----
    table: Dict[str, List[Tuple[str, str]]] = {}
    for raw in lines:
        line = raw.strip()
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rm = _RESULT_RE.search(line)
        if rm:
            table[dm.group(1)] = _SHAPE_RE.findall(rm.group(1))

    # ---- pass 2: per-computation stats ----
    comps: Dict[str, CompStats] = defaultdict(CompStats)
    consts: Dict[str, int] = {}
    entry = None
    current = None
    for raw in lines:
        line = raw.strip()
        if "->" in line and line.endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                current = m.group(2)
                if m.group(1):
                    entry = current
                continue
        if current is None or not line or line == "}":
            continue
        cm3 = _CONST_RE.search(line)
        if cm3:
            consts[current] = max(consts.get(current, 0), int(cm3.group(1)))
        rm = _RESULT_RE.search(line)
        if rm is None:
            continue
        st = comps[current]
        opname = rm.group(2)
        result_shapes = _SHAPE_RE.findall(rm.group(1))
        # operands: %names inside the op's parens
        args_seg = ""
        paren = line.find(opname + "(", rm.start(2))
        if paren >= 0:
            depth = 0
            start = paren + len(opname) + 1
            for j in range(start, len(line)):
                if line[j] == "(":
                    depth += 1
                elif line[j] == ")":
                    if depth == 0:
                        args_seg = line[start:j]
                        break
                    depth -= 1
        operands = _OPERAND_RE.findall(args_seg)

        base = opname.replace("-start", "")
        if base in _COLLECTIVES:
            op_shapes = [s for o in operands for s in table.get(o, [])]
            st.coll[base] += _shapes_bytes(op_shapes or result_shapes)
        elif opname == "dot":
            lcm = _LHS_CONTRACT_RE.search(line)
            if operands and lcm is not None:
                lhs = table.get(operands[0], [])
                if lhs:
                    ldims = _dims_of(lhs[0][1])
                    contract = 1
                    for i in _dims_of(lcm.group(1)):
                        if i < len(ldims):
                            contract *= ldims[i]
                    out = 1
                    for d in (_dims_of(result_shapes[0][1])
                              if result_shapes else []):
                        out *= d
                    st.flops += 2.0 * out * contract
        if opname == "while":
            wm = _WHILE_RE.search(line)
            if wm:
                st.whiles.append((wm.group(1), wm.group(2)))
        elif opname in ("fusion", "call", "map", "reduce", "sort", "scatter",
                        "reduce-window", "select-and-scatter"):
            cm2 = _CALLS_RE.search(line)
            if cm2:
                st.calls.append(cm2.group(1))
        elif opname == "conditional":
            bm = _COND_BRANCH_RE.search(line)
            if bm:
                st.calls.extend(b.strip().lstrip("%")
                                for b in bm.group(1).split(","))
        if opname in _INPLACE_OPS:
            # aliased update: count the update operand (read+write), not the
            # full buffer (donated/in-place on TPU)
            upd = (table.get(operands[1], []) if len(operands) > 1 else [])
            st.bytes += 2 * _shapes_bytes(upd)
        elif opname not in _SKIP_BYTES_OPS:
            op_shapes = [s for o in operands for s in table.get(o, [])]
            st.bytes += _shapes_bytes(result_shapes) + _shapes_bytes(op_shapes)
    return comps, consts, entry


def expanded_totals(text: str) -> Dict:
    comps, consts, entry = parse_hlo(text)
    memo: Dict[str, Dict] = {}

    def walk(name: str, depth=0) -> Dict:
        if name in memo:
            return memo[name]
        if depth > 64 or name not in comps:
            return {"coll": {}, "flops": 0.0, "bytes": 0.0}
        st = comps[name]
        out = {"coll": dict(st.coll), "flops": st.flops, "bytes": st.bytes}
        for callee in st.calls:
            sub = walk(callee, depth + 1)
            out["flops"] += sub["flops"]       # fusion interior dots count
            for k, v in sub["coll"].items():
                out["coll"][k] = out["coll"].get(k, 0.0) + v
            # fusion interior bytes intentionally NOT added (HBM boundary)
        for cond, body in st.whiles:
            trips = max(consts.get(cond, 1), 1)
            sub = walk(body, depth + 1)
            out["flops"] += trips * sub["flops"]
            out["bytes"] += trips * sub["bytes"]
            for k, v in sub["coll"].items():
                out["coll"][k] = out["coll"].get(k, 0.0) + trips * v
        memo[name] = out
        return out

    if entry is None:
        agg = {"coll": defaultdict(float), "flops": 0.0, "bytes": 0.0}
        for st in comps.values():
            agg["flops"] += st.flops
            agg["bytes"] += st.bytes
            for k, v in st.coll.items():
                agg["coll"][k] += v
        agg["coll"] = dict(agg["coll"])
        return agg
    return walk(entry)


def analyze_compiled(compiled) -> Dict:
    text = compiled.as_text()
    tot = expanded_totals(text)
    total = sum(tot["coll"].values())
    counts = {k: len(re.findall(rf"\b{k}(-start)?\(", text))
              for k in _COLLECTIVES}
    return {
        "bytes_by_kind": {k: float(v) for k, v in tot["coll"].items()},
        "total_bytes": float(total),
        "expanded_dot_flops": float(tot["flops"]),
        "expanded_hbm_bytes": float(tot["bytes"]),
        "static_op_counts": counts,
        "summary": (f"total={total/2**30:.3f}GiB  "
                    + "  ".join(f"{k}={v/2**30:.3f}GiB"
                                for k, v in sorted(tot["coll"].items()))),
    }
