"""Production mesh construction.

Deliberately a FUNCTION (no module-level jax device access) so importing
this module never locks jax's device count — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: 'pod' (slow inter-pod links) × 'data' (client/batch parallelism +
    FSDP) × 'model' (tensor parallelism).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small CPU meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
