"""Serving driver: batched prefill + decode over the split/served model.

CPU demo:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --batch 2 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill

# jit'd decode_step per ModelConfig (hashable, frozen): repeated generate()
# calls reuse the compiled executable instead of re-tracing a fresh lambda
# (jax.jit caches by function identity) on every request
_DECODE_STEP = {}


def decode_step_jit(cfg):
    fn = _DECODE_STEP.get(cfg)
    if fn is None:
        fn = jax.jit(functools.partial(decode_step, cfg))
        _DECODE_STEP[cfg] = fn
    return fn


def generate(cfg, params, batch, prompt_len: int, gen: int, *,
             temperature: float = 0.0, key=None):
    """Greedy / temperature sampling after a batched prefill."""
    B = batch["tokens"].shape[0]
    cache_len = prompt_len + gen
    logits, cache = prefill(cfg, params, batch, cache_len=cache_len)
    out = []
    step = decode_step_jit(cfg)
    tok = None
    for i in range(gen):
        if temperature > 0 and key is not None:
            key, k2 = jax.random.split(key)
            tok = jax.random.categorical(k2, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        logits, cache = step(params, tok, cache, jnp.int32(prompt_len + i))
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    k_params, k_tok, k_img, k_audio = jax.random.split(key, 4)
    params = init_params(cfg, k_params)
    batch = {"tokens": jax.random.randint(k_tok,
                                          (args.batch, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.n_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            k_img, (args.batch, cfg.n_image_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            k_audio, (args.batch, cfg.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    toks = generate(cfg, params, batch, args.prompt_len, args.gen)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks)


if __name__ == "__main__":
    main()
