"""Serving driver: batched prefill + decode over the split/served model.

CPU demo:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --batch 2 --prompt-len 16 --gen 8

Observability: every ``generate`` call records serve.requests /
serve.tokens counters and a serve.generate_seconds histogram in the
process-wide obs registry, with spans around prefill and the decode loop.
``stats()`` is the JSON stats surface; ``--stats`` prints it after the
demo request and ``--stats-port N`` serves it at GET /stats from a
background stdlib HTTP server (the same snapshot a fleet scraper would
poll).
"""
from __future__ import annotations

import argparse
import functools
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from time import perf_counter

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill

_T_START = time.time()

# jit'd decode_step per ModelConfig (hashable, frozen): repeated generate()
# calls reuse the compiled executable instead of re-tracing a fresh lambda
# (jax.jit caches by function identity) on every request
_DECODE_STEP = {}


def decode_step_jit(cfg):
    fn = _DECODE_STEP.get(cfg)
    if fn is None:
        fn = jax.jit(functools.partial(decode_step, cfg))
        _DECODE_STEP[cfg] = fn
    return fn


def generate(cfg, params, batch, prompt_len: int, gen: int, *,
             temperature: float = 0.0, key=None):
    """Greedy / temperature sampling after a batched prefill."""
    B = batch["tokens"].shape[0]
    cache_len = prompt_len + gen
    reg = obs.get_registry()
    t0 = perf_counter()
    with obs.span("serve.generate", batch=B, gen=gen):
        with obs.span("serve.prefill", prompt_len=prompt_len):
            logits, cache = prefill(cfg, params, batch, cache_len=cache_len)
        out = []
        step = decode_step_jit(cfg)
        tok = None
        with obs.span("serve.decode", gen=gen):
            for i in range(gen):
                if temperature > 0 and key is not None:
                    key, k2 = jax.random.split(key)
                    tok = jax.random.categorical(
                        k2, logits[:, -1] / temperature)[:, None]
                else:
                    tok = jnp.argmax(logits[:, -1],
                                     axis=-1)[:, None].astype(jnp.int32)
                out.append(tok)
                logits, cache = step(params, tok, cache,
                                     jnp.int32(prompt_len + i))
        toks = jax.block_until_ready(jnp.concatenate(out, axis=1))
    dt = perf_counter() - t0
    reg.counter("serve.requests").inc()
    reg.counter("serve.tokens").inc(B * gen)
    reg.histogram("serve.generate_seconds").observe(dt)
    reg.gauge("serve.last_tok_per_s").set(B * gen / dt if dt else 0.0)
    return toks


def stats() -> dict:
    """The stats surface: uptime + the obs metrics snapshot."""
    return {"uptime_s": round(time.time() - _T_START, 3),
            "metrics": obs.get_registry().snapshot()}


class _StatsHandler(BaseHTTPRequestHandler):
    def do_GET(self):
        if self.path not in ("/stats", "/"):
            self.send_error(404)
            return
        body = json.dumps(stats(), sort_keys=True).encode()
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):        # keep request noise off stdout
        pass


def serve_stats(port: int) -> ThreadingHTTPServer:
    """Start the background stats endpoint; returns the server (call
    .shutdown() to stop). Bound to localhost — it reports process
    metrics, it is not a public API."""
    srv = ThreadingHTTPServer(("127.0.0.1", port), _StatsHandler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats", action="store_true",
                    help="print the JSON stats snapshot after the request")
    ap.add_argument("--stats-port", type=int, default=0,
                    help="serve GET /stats on 127.0.0.1:PORT (0 = off)")
    args = ap.parse_args(argv)

    srv = serve_stats(args.stats_port) if args.stats_port else None
    if srv is not None:
        print(f"stats: http://127.0.0.1:{srv.server_address[1]}/stats")

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    k_params, k_tok, k_img, k_audio = jax.random.split(key, 4)
    params = init_params(cfg, k_params)
    batch = {"tokens": jax.random.randint(k_tok,
                                          (args.batch, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.n_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            k_img, (args.batch, cfg.n_image_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            k_audio, (args.batch, cfg.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    toks = generate(cfg, params, batch, args.prompt_len, args.gen)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks)
    if args.stats:
        print(json.dumps(stats(), indent=2, sort_keys=True))
    if srv is not None:
        srv.shutdown()


if __name__ == "__main__":
    main()
