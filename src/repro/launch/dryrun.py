import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and record memory/cost/collective numbers.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the dry-run needs 512 placeholder
host devices to build the 2×16×16 mesh. Do NOT export this flag anywhere
else (tests/benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k --multi-pod --out /tmp/dryrun.json
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, SHAPES_BY_NAME, get_config
from repro.configs.registry import ASSIGNED, cells
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell


def run_cell(arch, shape, mesh, mesh_name, *, tau=2, aggregation="dense",
             verbose=True):
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, tau=tau, aggregation=aggregation)
    lowered = lower_cell(cell)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = analyze_compiled(compiled)
    rec = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name,
        "status": "ok",
        "plan": {"client_mode": cell.plan.client_mode,
                 "fsdp": cell.plan.fsdp,
                 "aggregation": cell.plan.aggregation},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                       + getattr(mem, "output_size_in_bytes", 0)
                       + getattr(mem, "temp_size_in_bytes", 0)),
        "collectives": coll,
    }
    if verbose:
        n_dev = mesh.devices.size
        print(f"  plan={rec['plan']}  lower={t_lower:.0f}s "
              f"compile={t_compile:.0f}s")
        print(f"  memory_analysis: args={rec['argument_size_bytes']/2**30:.2f}GiB "
              f"out={rec['output_size_bytes']/2**30:.2f}GiB "
              f"temp={rec['temp_size_bytes']/2**30:.2f}GiB "
              f"(whole-program; ÷{n_dev} devices = "
              f"{rec['peak_bytes']/n_dev/2**30:.3f}GiB/device)")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e}")
        print(f"  collectives: {coll['summary']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--aggregation", default="dense",
                    choices=["dense", "seed_replay"])
    ap.add_argument("--out", default="/root/repo/dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(("16x16", make_production_mesh(multi_pod=False)))
    if args.multi_pod or not args.single_pod:
        meshes.append(("2x16x16", make_production_mesh(multi_pod=True)))

    todo = []
    for arch, shape, status in cells(include_skips=True):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        todo.append((arch, shape, status))

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    failures = 0
    for mesh_name, mesh in meshes:
        for arch, shape, status in todo:
            tag = f"{arch} × {shape.name} × {mesh_name}"
            if status.startswith("skip"):
                print(f"[skip] {tag}: {status}")
                results.append({"arch": arch, "shape": shape.name,
                                "mesh": mesh_name, "status": status})
                continue
            print(f"[dry-run] {tag}")
            try:
                results.append(run_cell(arch, shape, mesh, mesh_name,
                                        tau=args.tau,
                                        aggregation=args.aggregation))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape.name,
                                "mesh": mesh_name, "status": f"FAIL: {e}"})
            json.dump(results, open(args.out, "w"), indent=1)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n== dry-run: {ok} ok, {failures} failed, "
          f"{sum(1 for r in results if str(r.get('status')).startswith('skip'))} skipped "
          f"-> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
