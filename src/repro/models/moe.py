"""Mixture-of-Experts block: top-k routing with static-shape, sort-based
capacity dispatch (MegaBlocks/GShard hybrid — no (N, E, C) one-hot tensors),
shared always-on experts (DeepSeek-V2 style), and a load-balancing aux loss.

Expert weights carry a leading E dim so expert-parallelism is a pure
sharding decision (E over the ``model`` axis when divisible, else the expert
FFN hidden dim is tensor-parallel and E replicated — the planner decides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_mlp, apply_mlp_expert, dense_init, init_mlp


def moe_dims(cfg: ModelConfig):
    m = cfg.moe
    d_expert = m.d_expert if m.d_expert > 0 else cfg.d_ff
    return m.n_experts, m.top_k, m.n_shared, d_expert


def init_moe(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    E, k, n_shared, d_e = moe_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "wi": dense_init(ks[1], (E, d, d_e), dtype),
        "wo": dense_init(ks[3], (E, d_e, d), dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["wg"] = dense_init(ks[2], (E, d, d_e), dtype)
    if n_shared > 0:
        p["shared"] = init_mlp(cfg, ks[4], d, n_shared * d_e)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, (c + 7) // 8 * 8)   # MXU-friendly multiple of 8


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar f32).

    Dispatch is GROUPED per batch row: each sequence routes/sorts/scatters
    its own tokens with a per-group capacity, so with batch sharded over
    'data' the whole dispatch is shard-LOCAL — no cross-device argsort or
    scatter resharding (found via the §Perf iteration on jamba: a global
    N-token sort cost TBs of collective-permute per round). Experts stay
    EP-sharded over 'model'; only the expert einsums touch that axis.
    """
    B, S, D = x.shape
    out, aux = jax.vmap(lambda xb: _moe_one_group(cfg, p, xb))(x)
    return out, jnp.mean(aux)


def _moe_one_group(cfg: ModelConfig, p, x):
    """x: (N, D) one group's tokens -> (out (N, D), aux scalar)."""
    N, D = x.shape
    E, k, n_shared, d_e = moe_dims(cfg)
    C = capacity(cfg, N)
    xf = x

    # --- routing (f32) ---
    logits = (xf.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)                    # (N, k)
    gate_k = gate_k / jnp.sum(gate_k, axis=-1, keepdims=True)  # renormalize

    # aux load-balancing loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx_k[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- static-shape sort-based dispatch ---
    e_flat = idx_k.reshape(-1)                                 # (N*k,)
    g_flat = gate_k.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(N), k)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    order = jnp.argsort(e_flat, stable=True)
    rank_sorted = jnp.arange(N * k, dtype=jnp.int32) - starts[e_flat[order]]
    rank = jnp.zeros((N * k,), jnp.int32).at[order].set(rank_sorted)
    kept = rank < C
    slot = jnp.where(kept, rank, C)                            # trash slot C

    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[e_flat, slot].set(xf[tok_flat])
    expert_in = buf[:, :C]                                     # (E, C, D)

    # --- expert FFNs (batched per-expert matmuls; EP-shardable on E) ---
    expert_out = apply_mlp_expert(cfg, p, expert_in)           # (E, C, D)

    # --- combine ---
    gathered = expert_out[e_flat, jnp.minimum(slot, C - 1)]    # (N*k, D)
    w = jnp.where(kept, g_flat, 0.0).astype(x.dtype)[:, None]
    out = jnp.zeros((N, D), x.dtype).at[tok_flat].add(gathered * w)

    if n_shared > 0:
        out = out + apply_mlp(cfg, p["shared"], xf)
    return out, aux
