"""State-space / recurrent blocks: Mamba (selective scan) and xLSTM
(mLSTM chunkwise-parallel, sLSTM sequential).

Each block exposes three entry points:
    init_*         parameters
    *_forward      full-sequence (train / prefill); returns (y, final_state)
    *_decode       single-token step on a carried state (serve decode)

States are pure pytrees so they slot into the generic cache machinery.
Sequence processing is chunked (``cfg.*.chunk``) so the lowered HLO is a
short scan of MXU-friendly blocks, not a token-level loop — this is the
TPU adaptation of the CUDA selective-scan kernel (VMEM-resident chunk state,
matmul-heavy intra-chunk math).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

# ===========================================================================
# Mamba
# ===========================================================================

def mamba_dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank if m.dt_rank > 0 else int(np.ceil(cfg.d_model / 16))
    return d_inner, m.d_state, m.d_conv, dt_rank, m.chunk


def init_mamba(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_in, N, d_conv, dt_rank, _ = mamba_dims(cfg)
    ks = jax.random.split(key, 7)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (d_conv, d_in), dtype, scale=1.0 / np.sqrt(d_conv)),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, jnp.float32))),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), dtype),
    }


def mamba_init_state(cfg: ModelConfig, batch: int):
    d_in, N, d_conv, _, _ = mamba_dims(cfg)
    return {"h": jnp.zeros((batch, d_in, N), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_in), jnp.dtype(cfg.dtype))}


def _mamba_conv_full(p, x, d_conv):
    """Causal depthwise conv over (B, S, d_in)."""
    B, S, d_in = x.shape
    xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(d_conv):                               # d_conv is tiny (4)
        out = out + xp[:, i:i + S].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)


def _ssm_scan_chunk(a, b, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t within one chunk.

    a, b: (L, B, d_in, N) f32; h0: (B, d_in, N). Returns (h_all, h_last).
    """
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2
    a_c, b_c = jax.lax.associative_scan(op, (a, b), axis=0)
    h_all = a_c * h0[None] + b_c
    return h_all, h_all[-1]


def _mamba_ssm_params(cfg, p, xs):
    """xs: (B, L, d_in) post-conv activations -> (dA, dBx, C) f32."""
    d_in, N, _, dt_rank, _ = mamba_dims(cfg)
    x_dbl = (xs @ p["x_proj"]).astype(jnp.float32)
    dt, Bc, Cc = jnp.split(x_dbl, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])  # (B,L,d_in)
    A = -jnp.exp(p["A_log"])                                        # (d_in, N)
    dA = jnp.exp(dt[..., None] * A[None, None])                     # (B,L,d_in,N)
    dBx = (dt * xs.astype(jnp.float32))[..., None] * Bc[:, :, None, :]
    return dA, dBx, Cc


def mamba_forward(cfg: ModelConfig, p, x, state=None):
    """x: (B, S, D) -> (y (B, S, D), final_state)."""
    B, S, D = x.shape
    d_in, N, d_conv, dt_rank, chunk = mamba_dims(cfg)
    if state is None:
        state = mamba_init_state(cfg, B)
    xz = x @ p["in_proj"]
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_mamba_conv_full(p, xs_raw, d_conv))

    L = min(chunk, S)
    n_chunks = S // L
    rem = S - n_chunks * L

    def body(h, xs_chunk):
        dA, dBx, Cc = _mamba_ssm_params(cfg, p, xs_chunk)           # (B,L,...)
        h_all, h_last = _ssm_scan_chunk(dA.swapaxes(0, 1), dBx.swapaxes(0, 1), h)
        y = jnp.einsum("lbdn,bln->bld", h_all, Cc)                  # (B,L,d_in)
        return h_last, y

    xs_c = xs[:, :n_chunks * L].reshape(B, n_chunks, L, d_in).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(body, state["h"], xs_c)
    ys = ys.swapaxes(0, 1).reshape(B, n_chunks * L, d_in)
    if rem:                                                          # tail chunk
        h_last, y_tail = body(h_last, xs[:, n_chunks * L:])
        ys = jnp.concatenate([ys, y_tail], axis=1)
    y = (ys + xs.astype(jnp.float32) * p["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    new_state = {"h": h_last, "conv": xs_raw[:, S - (d_conv - 1):, :]
                 if S >= d_conv - 1 else state["conv"]}
    return y @ p["out_proj"], new_state


def mamba_decode(cfg: ModelConfig, p, x, state):
    """x: (B, 1, D) -> (y (B, 1, D), state)."""
    B = x.shape[0]
    d_in, N, d_conv, dt_rank, _ = mamba_dims(cfg)
    xz = x[:, 0] @ p["in_proj"]
    xs_raw, z = jnp.split(xz, 2, axis=-1)                           # (B, d_in)
    conv_buf = jnp.concatenate([state["conv"], xs_raw[:, None]], axis=1)  # (B,d_conv,d_in)
    acc = jnp.einsum("bcd,cd->bd", conv_buf.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xs = jax.nn.silu(acc.astype(x.dtype))                           # (B, d_in)
    dA, dBx, Cc = _mamba_ssm_params(cfg, p, xs[:, None])
    h = dA[:, 0] * state["h"] + dBx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])
    y = (y + xs.astype(jnp.float32) * p["D"]).astype(x.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None], {"h": h, "conv": conv_buf[:, 1:]}


# ===========================================================================
# mLSTM (xLSTM matrix-memory, chunkwise-parallel)
# ===========================================================================

def mlstm_dims(cfg: ModelConfig):
    xc = cfg.xlstm
    d_up = int(xc.proj_factor * cfg.d_model)
    H = xc.n_heads
    d_up = (d_up // H) * H
    return d_up, H, d_up // H, xc.chunk


def init_mlstm(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_up, H, dh, _ = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_up), dtype),
        "wq": dense_init(ks[1], (d_up, d_up), dtype),
        "wk": dense_init(ks[2], (d_up, d_up), dtype),
        "wv": dense_init(ks[3], (d_up, d_up), dtype),
        "w_i": dense_init(ks[4], (d_up, H), jnp.float32, scale=0.02),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[5], (d_up, H), jnp.float32, scale=0.02),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # forget-open init
        "w_down": dense_init(ks[6], (d_up, d), dtype),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int):
    _, H, dh, _ = mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def _mlstm_qkv_gates(cfg, p, x):
    B, S, _ = x.shape
    d_up, H, dh, _ = mlstm_dims(cfg)
    up = x @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"]).reshape(B, S, H, dh)
    k = ((u @ p["wk"]) / np.sqrt(dh)).reshape(B, S, H, dh)
    v = (u @ p["wv"]).reshape(B, S, H, dh)
    uf = u.astype(jnp.float32)
    li = uf @ p["w_i"] + p["b_i"]                                   # log input gate
    lf = jax.nn.log_sigmoid(uf @ p["w_f"] + p["b_f"])               # log forget gate
    return q, k, v, li, lf, z


def mlstm_forward(cfg: ModelConfig, p, x, state=None):
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (y, final_state)."""
    B, S, D = x.shape
    d_up, H, dh, chunk = mlstm_dims(cfg)
    if state is None:
        state = mlstm_init_state(cfg, B)
    q, k, v, li, lf, z = _mlstm_qkv_gates(cfg, p, x)
    L = min(chunk, S)
    nc = S // L
    rem = S - nc * L
    Sm = nc * L

    def resh(t, last):
        return t[:, :Sm].reshape((B, nc, L) + last).swapaxes(0, 1)
    qc, kc, vc = resh(q, (H, dh)), resh(k, (H, dh)), resh(v, (H, dh))
    lic, lfc = resh(li, (H,)), resh(lf, (H,))

    def body(carry, xs):
        C0, n0, m0 = carry
        qx, kx, vx, lix, lfx = xs                                   # (B,Lc,H,*)
        Lc = qx.shape[1]
        csum = jnp.cumsum(lfx, axis=1)                              # (B,Lc,H)
        # intra-chunk decay: D[t,s] = csum_t - csum_s + li_s  (s <= t)
        Dm = (csum[:, :, None, :] - csum[:, None, :, :]
              + lix[:, None, :, :])                                 # (B,Lc,Lc,H)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
        m_intra = jnp.max(Dm, axis=2)                               # (B,L,H)
        m_inter = csum + m0[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)                         # (B,L,H)
        # inter contribution
        sc_inter = jnp.exp(m_inter - m_t)                           # (B,L,H)
        qf = qx.astype(jnp.float32)
        h_inter = jnp.einsum("blhd,bhde->blhe", qf, C0) * sc_inter[..., None]
        d_inter = jnp.einsum("blhd,bhd->blh", qf, n0) * sc_inter
        # intra contribution
        w = jnp.exp(Dm - m_t[:, :, None, :])                        # (B,L,L,H)
        scores = jnp.einsum("blhd,bshd->blsh", qf, kx.astype(jnp.float32)) * w
        h_intra = jnp.einsum("blsh,bshe->blhe", scores, vx.astype(jnp.float32))
        d_intra = jnp.sum(scores, axis=2)                           # (B,L,H)
        denom = jnp.maximum(jnp.abs(d_inter + d_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / denom[..., None]                  # (B,L,H,dh)
        # end-of-chunk state
        tot = csum[:, -1, :]                                        # (B,H)
        dec_s = tot[:, None, :] - csum + lix                        # (B,L,H)
        m_C = jnp.maximum(m0 + tot, jnp.max(dec_s, axis=1))         # (B,H)
        wC = jnp.exp(dec_s - m_C[:, None, :])                       # (B,L,H)
        C_new = (jnp.exp(m0 + tot - m_C)[..., None, None] * C0
                 + jnp.einsum("blh,blhd,blhe->bhde",
                              wC, kx.astype(jnp.float32), vx.astype(jnp.float32)))
        n_new = (jnp.exp(m0 + tot - m_C)[..., None] * n0
                 + jnp.einsum("blh,blhd->bhd", wC, kx.astype(jnp.float32)))
        return (C_new, n_new, m_C), h

    (C, n, m), hs = jax.lax.scan(body, (state["C"], state["n"], state["m"]),
                                 (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, Sm, H * dh)
    if rem:                                                          # tail chunk
        (C, n, m), h_tail = body((C, n, m),
                                 (q[:, Sm:], k[:, Sm:], v[:, Sm:],
                                  li[:, Sm:], lf[:, Sm:]))
        h = jnp.concatenate([h, h_tail.reshape(B, rem, H * dh)], axis=1)
    h = h.astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y, {"C": C, "n": n, "m": m}


def mlstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    d_up, H, dh, _ = mlstm_dims(cfg)
    q, k, v, li, lf, z = _mlstm_qkv_gates(cfg, p, x)                # S=1
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    li, lf, z = li[:, 0], lf[:, 0], z[:, 0]
    m_new = jnp.maximum(lf + state["m"], li)                        # (B,H)
    fs = jnp.exp(lf + state["m"] - m_new)
    is_ = jnp.exp(li - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = fs[..., None, None] * state["C"] + is_[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = fs[..., None] * state["n"] + is_[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, d_up).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y[:, None], {"C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM (scalar-memory, strictly sequential)
# ===========================================================================

def slstm_dims(cfg: ModelConfig):
    H = cfg.xlstm.n_heads
    return H, cfg.d_model // H


def init_slstm(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    H, dh = slstm_dims(cfg)
    ks = jax.random.split(key, 6)
    d_ff = int(cfg.xlstm.slstm_proj_factor * d)
    return {
        "w_x": dense_init(ks[0], (d, 4 * d), dtype),         # z,i,f,o pre-acts
        "r": dense_init(ks[1], (4, H, dh, dh), jnp.float32,
                        scale=1.0 / np.sqrt(dh)),            # block-diag recurrent
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "w_up": dense_init(ks[2], (d, 2 * d_ff), dtype),     # GeGLU post-ffn
        "w_down": dense_init(ks[3], (d_ff, d), dtype),
    }


def slstm_init_state(cfg: ModelConfig, batch: int):
    H, dh = slstm_dims(cfg)
    return {"c": jnp.zeros((batch, H, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
            "h": jnp.zeros((batch, H, dh), jnp.float32)}


def _slstm_step(cfg, p, state, x_pre):
    """x_pre: (B, 4*D) token pre-activations. Returns (state, h_out (B,D))."""
    H, dh = slstm_dims(cfg)
    B = x_pre.shape[0]
    rec = jnp.einsum("bhd,ghde->bghe", state["h"], p["r"])          # (B,4,H,dh)
    pre = (x_pre.astype(jnp.float32) + p["b"]).reshape(B, 4, H, dh) + rec
    z_r, i_r, f_r, o_r = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    lf = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(lf + state["m"], i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(lf + state["m"] - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c = f_g * state["c"] + i_g * z
    n = jnp.maximum(f_g * state["n"] + i_g, jnp.exp(-m_new))
    h = o * c / n
    return ({"c": c, "n": n, "m": m_new, "h": h}, h.reshape(B, H * dh))


def slstm_forward(cfg: ModelConfig, p, x, state=None):
    B, S, D = x.shape
    if state is None:
        state = slstm_init_state(cfg, B)
    x_pre = x @ p["w_x"]                                            # (B,S,4D)

    def body(st, xp):
        st, h = _slstm_step(cfg, p, st, xp)
        return st, h
    state, hs = jax.lax.scan(body, state, x_pre.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                           # (B,S,D)
    up = h @ p["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["w_down"]
    return y, state


def slstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    x_pre = (x[:, 0] @ p["w_x"])
    state, h = _slstm_step(cfg, p, state, x_pre)
    h = h.astype(x.dtype)
    up = h @ p["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["w_down"]
    return y[:, None], state
