"""Primitive layers: norms, RoPE, MLPs, embeddings.

All functions are pure; parameters are plain dicts of jnp arrays. Norm math
runs in float32 and casts back to the input dtype (standard mixed-precision
practice on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, key, d: int):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparam_ln":      # OLMo: no learned affine
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            out = out * p["scale"] + p["bias"]
    return out.astype(dtype)


def rms_norm_simple(x, scale, eps: float = 1e-5):
    """Standalone RMSNorm used for qk-norm / MLA latent norms."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_rot: int, theta: float):
    """Inverse frequencies for rotary embedding over the first d_rot dims."""
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x, positions, theta: float, d_rot: int | None = None):
    """x: (..., S, d_head); positions: broadcastable to (..., S).

    Rotates the first ``d_rot`` dims (full head dim by default); the rest
    pass through (MLA rotates only qk_rope_dim).
    """
    d_head = x.shape[-1]
    if d_rot is None:
        d_rot = d_head
    inv = rope_freqs(d_rot, theta)                                  # (d_rot/2,)
    # explicit rank alignment: (..., S, 1) x (1, ..., 1, d_rot/2) — keeps
    # the op legal under jax_numpy_rank_promotion='raise'
    inv = inv.reshape((1,) * positions.ndim + (-1,))
    ang = positions[..., None].astype(jnp.float32) * inv            # (..., S, d_rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rot, xp], axis=-1) if d_rot < d_head else rot


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_model: int, d_ff: int):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {"wi": dense_init(k1, (d_model, d_ff), dtype),
                "wg": dense_init(k2, (d_model, d_ff), dtype),
                "wo": dense_init(k3, (d_ff, d_model), dtype)}
    return {"wi": dense_init(k1, (d_model, d_ff), dtype),
            "wo": dense_init(k3, (d_ff, d_model), dtype)}


def apply_mlp(cfg: ModelConfig, p, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]


# expert-parallel variant: weights have a leading expert dim (E, ...)
def apply_mlp_expert(cfg: ModelConfig, p, x):
    """x: (E, C, D); weights (E, D, F)/(E, F, D). Batched per-expert matmul."""
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["wi"]))
        h = h * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["wi"]))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])
