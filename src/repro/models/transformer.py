"""Transformer assembly: unit-scanned heterogeneous blocks, the SFL
split-point machinery (client prefix / server suffix at any unit boundary),
chunked cross-entropy, and prefill/decode serving paths.

Layer parameters are stacked along a leading ``n_units`` dim and consumed by
``lax.scan`` so compile time and HLO size are independent of depth. A "unit"
is one repetition of ``cfg.block_pattern`` (e.g. jamba's 8-layer
mamba/attn interleave); the SFL cut lands on unit boundaries.

Batch conventions
    LM     : {"tokens": (B,S) i32, "labels": (B,S) i32}
    VLM    : + {"image_embeds": (B, I, D)}
    audio  : {"frames": (B, F, D)} + tokens/labels for the decoder
    decode : {"token": (B,1) i32}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.layers import (apply_mlp, apply_norm, dense_init, embed_init,
                                 init_mlp, init_norm)

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01


# ===========================================================================
# init
# ===========================================================================

def _init_block(cfg: ModelConfig, key, btype: str, pos_in_unit: int) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg, ks[0], cfg.d_model)}
    if btype == "attn":
        p["core"] = attn.init_attn(cfg, ks[1])
    elif btype == "xattn":
        p["core"] = attn.init_xattn(cfg, ks[1])
    elif btype == "mamba":
        p["core"] = ssm.init_mamba(cfg, ks[1])
    elif btype == "mlstm":
        p["core"] = ssm.init_mlstm(cfg, ks[1])
    elif btype == "slstm":
        p["core"] = ssm.init_slstm(cfg, ks[1])
    elif btype == "dec":  # whisper decoder block: self-attn + cross-attn
        p["core"] = attn.init_attn(cfg, ks[1])
        p["norm_x"] = init_norm(cfg, ks[2], cfg.d_model)
        p["xattn"] = attn.init_xattn(cfg, ks[2])
    else:
        raise ValueError(btype)
    if _has_ffn(cfg, btype):
        p["norm2"] = init_norm(cfg, ks[2], cfg.d_model)
        if cfg.layer_uses_moe(pos_in_unit):
            p["ffn"] = moe_lib.init_moe(cfg, ks[3])
        else:
            p["ffn"] = init_mlp(cfg, ks[3], cfg.d_model, cfg.d_ff)
    return p


def _has_ffn(cfg: ModelConfig, btype: str) -> bool:
    if btype in ("mlstm", "slstm"):
        return False                      # xLSTM blocks are self-contained
    return cfg.d_ff > 0 or cfg.moe is not None


def _init_unit_stack(cfg: ModelConfig, key, pattern, n_units: int) -> Params:
    """vmap init over units -> leaves with leading n_units dim."""
    def one_unit(k):
        kk = jax.random.split(k, len(pattern))
        return {f"b{j}": _init_block(cfg, kk[j], bt, j)
                for j, bt in enumerate(pattern)}
    return jax.vmap(one_unit)(jax.random.split(key, n_units))


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    params: Params = {"embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype)}
    if cfg.is_encoder_decoder:
        enc_units = cfg.n_encoder_layers  # encoder pattern = ("attn",)
        params["audio_proj"] = dense_init(ks[1], (cfg.d_model, cfg.d_model), dtype)
        params["enc_units"] = _init_unit_stack(cfg, ks[2], ("attn",), enc_units)
        params["enc_norm"] = init_norm(cfg, ks[3], cfg.d_model)
        params["units"] = _init_unit_stack(cfg, ks[4], ("dec",), cfg.n_layers)
    else:
        if cfg.n_image_tokens > 0:
            params["image_proj"] = dense_init(ks[1], (cfg.d_model, cfg.d_model), dtype)
        params["units"] = _init_unit_stack(cfg, ks[4], cfg.block_pattern, cfg.n_units)
    params["final_norm"] = init_norm(cfg, ks[5], cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[6], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ===========================================================================
# block application (full-sequence)
# ===========================================================================

def _apply_block(cfg: ModelConfig, p: Params, btype: str, x, positions, ctx,
                 *, causal: bool):
    """One block, full sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)
    if btype == "attn":
        if cfg.attn_impl == "mla":
            x = x + attn.mla_attention(cfg, p["core"], h, positions, causal=causal)
        else:
            x = x + attn.gqa_attention(cfg, p["core"], h, positions, causal=causal)
    elif btype == "xattn":
        x = x + attn.cross_attention(cfg, p["core"], h, ctx, gated=True)
    elif btype == "mamba":
        y, _ = ssm.mamba_forward(cfg, p["core"], h)
        x = x + y
    elif btype == "mlstm":
        y, _ = ssm.mlstm_forward(cfg, p["core"], h)
        x = x + y
    elif btype == "slstm":
        y, _ = ssm.slstm_forward(cfg, p["core"], h)
        x = x + y
    elif btype == "dec":
        x = x + attn.gqa_attention(cfg, p["core"], h, positions, causal=True)
        hx = apply_norm(cfg, p["norm_x"], x)
        x = x + attn.cross_attention(cfg, p["xattn"], hx, ctx)
    if _has_ffn(cfg, btype):
        h2 = apply_norm(cfg, p["norm2"], x)
        # MoE-vs-MLP is static per pattern position; decided by param structure:
        if "router" in p["ffn"]:
            y, a = moe_lib.apply_moe(cfg, p["ffn"], h2)
            aux = aux + a
        else:
            y = apply_mlp(cfg, p["ffn"], h2)
        x = x + y
    return x, aux


def _unit_scan(cfg: ModelConfig, units: Params, x, positions, ctx, pattern,
               *, causal: bool = True, remat: bool = False):
    """Scan blocks over the stacked unit dim. Returns (x, aux_sum)."""
    def body(carry, unit_p):
        xx, aux = carry
        for j, bt in enumerate(pattern):
            xx, a = _apply_block(cfg, unit_p[f"b{j}"], bt, xx, positions, ctx,
                                 causal=causal)
            aux = aux + a
        return (xx, aux), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), units)
    return x, aux


# ===========================================================================
# embedding / frontends
# ===========================================================================

def _embed_tokens(cfg: ModelConfig, params: Params, tokens):
    return params["embed"][tokens]          # gather; (B,S,D)


def _context_stream(cfg: ModelConfig, params: Params, batch) -> Optional[jnp.ndarray]:
    """Image / encoder stream the main stack cross-attends to (or None)."""
    if cfg.n_image_tokens > 0:
        return batch["image_embeds"] @ params["image_proj"]
    return None


# ===========================================================================
# full forward / loss (with cut-point composition)
# ===========================================================================

def split_dims(cfg: ModelConfig, cut_units: int) -> Tuple[int, int]:
    """(d_c, d_s) parameter counts for a cut (used by theory + planner).
    Computed from abstract shapes (no allocation); tied models count the
    untied server head copy on the server side (split untangles the tie)."""
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    size = lambda t: sum(int(np_prod(x.shape)) for x in jax.tree.leaves(t))
    total = size(shapes)
    if cfg.is_encoder_decoder:
        per_enc = size(shapes["enc_units"]) // cfg.n_encoder_layers
        d_c = size(shapes["audio_proj"]) + cut_units * per_enc
    else:
        per_unit = size(shapes["units"]) // cfg.n_units
        d_c = size(shapes["embed"]) + cut_units * per_unit
        if cfg.n_image_tokens > 0:
            d_c += size(shapes["image_proj"])
    extra_head = 0 if "lm_head" in shapes else size(shapes["embed"])
    return d_c, total - d_c + extra_head


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def split_params(cfg: ModelConfig, params: Params, cut_units: int):
    """Split at a unit boundary: client = embed/frontends + units[:cut];
    server = units[cut:] + final norm + head. Enc-dec: the cut indexes
    encoder units; the whole decoder is server-side."""
    def take(tree, sl):
        return jax.tree.map(lambda a: a[sl], tree)
    cut = cut_units
    client: Params = {"embed": params["embed"]}
    server: Params = {"final_norm": params["final_norm"]}
    # Tied models are untied at the cut: the server owns its own head copy
    # (the tie cannot survive a client/server parameter split).
    server["lm_head"] = params.get("lm_head")
    if server["lm_head"] is None:
        server["lm_head"] = params["embed"].T      # (D, V) head layout
    if cfg.is_encoder_decoder:
        assert 1 <= cut <= cfg.n_encoder_layers
        client["audio_proj"] = params["audio_proj"]
        client["units"] = take(params["enc_units"], slice(0, cut))
        server["enc_units"] = take(params["enc_units"], slice(cut, None))
        server["enc_norm"] = params["enc_norm"]
        server["units"] = params["units"]
        server["embed"] = params["embed"]        # decoder token embedding
    else:
        assert 1 <= cut <= cfg.n_units
        if cfg.n_image_tokens > 0:
            client["image_proj"] = params["image_proj"]
        client["units"] = take(params["units"], slice(0, cut))
        server["units"] = take(params["units"], slice(cut, None))
    return client, server


def merge_params(cfg: ModelConfig, client: Params, server: Params) -> Params:
    """Inverse of split_params."""
    params: Params = {"final_norm": server["final_norm"]}
    if cfg.is_encoder_decoder:
        params["embed"] = server["embed"]
        params["audio_proj"] = client["audio_proj"]
        params["enc_units"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), client["units"],
            server["enc_units"])
        params["enc_norm"] = server["enc_norm"]
        params["units"] = server["units"]
    else:
        params["embed"] = client["embed"]
        if cfg.n_image_tokens > 0:
            params["image_proj"] = client["image_proj"]
        params["units"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), client["units"],
            server["units"])
    params["lm_head"] = server["lm_head"]
    return params


def untie_params(cfg: ModelConfig, params: Params) -> Params:
    """Give tied models an explicit head copy so split/merge round-trips keep
    a stable tree structure (call once at SFL-training setup)."""
    if "lm_head" in params:
        return params
    out = dict(params)
    out["lm_head"] = params["embed"].T             # (D, V) head layout
    return out


def client_forward(cfg: ModelConfig, client: Params, batch, *, remat: bool = False):
    """Client prefix -> cut-layer activation pytree ``h``."""
    if cfg.is_encoder_decoder:
        x = batch["frames"] @ client["audio_proj"]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, aux = _unit_scan(cfg, client["units"], x, positions, None,
                            ("attn",), causal=False, remat=remat)
        return {"h": x, "aux": aux}
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, client, tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    ctx = _context_stream(cfg, client, batch)
    x, aux = _unit_scan(cfg, client["units"], x, positions, ctx,
                        cfg.block_pattern, causal=True, remat=remat)
    out = {"h": x, "aux": aux}   # client-side MoE aux rides the cut
    if ctx is not None:
        out["ctx"] = ctx
    return out


def server_forward(cfg: ModelConfig, server: Params, h, batch, *,
                   remat: bool = False):
    """Server suffix from the cut activation -> scalar loss (f32)."""
    x = h["h"]
    aux = h.get("aux", jnp.zeros((), jnp.float32))
    if cfg.is_encoder_decoder:
        B, F, _ = x.shape
        pos_e = jnp.broadcast_to(jnp.arange(F), (B, F))
        x, _ = _unit_scan(cfg, server["enc_units"], x, pos_e, None, ("attn",),
                          causal=False, remat=remat)
        enc_out = apply_norm(cfg, server["enc_norm"], x)
        tokens = batch["tokens"]
        B, S = tokens.shape
        y = server["embed"][tokens]
        pos_d = jnp.broadcast_to(jnp.arange(S), (B, S))
        y, aux_d = _unit_scan(cfg, server["units"], y, pos_d, enc_out,
                              ("dec",), causal=True, remat=remat)
        aux = aux + aux_d
        x = y
    else:
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        ctx = h.get("ctx")
        x, aux_s = _unit_scan(cfg, server["units"], x, positions, ctx,
                              cfg.block_pattern, causal=True, remat=remat)
        aux = aux + aux_s
    x = apply_norm(cfg, server["final_norm"], x)
    loss = _chunked_ce(x, server["lm_head"], batch["labels"])
    return loss + MOE_AUX_COEF * aux


def forward_from_cut(cfg: ModelConfig, params: Params, batch, cut_units: int,
                     *, remat: bool = False):
    """Full loss via client/server composition (cut-invariant by design)."""
    cp, sp = split_params(cfg, params, cut_units)
    h = client_forward(cfg, cp, batch, remat=remat)
    return server_forward(cfg, sp, h, batch, remat=remat)


def loss_fn(cfg: ModelConfig, params: Params, batch, *, remat: bool = False):
    return forward_from_cut(cfg, params, batch, cfg.default_cut_units, remat=remat)


def _chunked_ce(x, head, labels, chunk: int = 2048):
    """Cross-entropy scanned over sequence chunks (bounds the (B,c,V) logits
    buffer; essential for 150k vocabs at 32k context)."""
    B, S, D = x.shape
    c = min(chunk, S)
    n = S // c
    rem = S - n * c

    def ce_of(xc, lc):
        # f32 accumulation directly out of the MXU: one f32 logits tensor
        # instead of bf16 logits + f32 convert (2x less CE traffic).
        logits = jnp.einsum("bsd,dv->bsv", xc, head,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].clip(0), axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        xc, lc = xs
        s, m = ce_of(xc, lc)
        return (carry[0] + s, carry[1] + m), None

    xm = x[:, :n * c].reshape(B, n, c, D).swapaxes(0, 1)
    lm = labels[:, :n * c].reshape(B, n, c).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xm, lm))
    if rem:
        s, m = ce_of(x[:, n * c:], labels[:, n * c:])
        tot, cnt = tot + s, cnt + m
    return tot / jnp.maximum(cnt, 1.0)


def logits_fn(cfg: ModelConfig, params: Params, batch):
    """Full-sequence logits (B, S, V) — small configs / tests only."""
    cp, sp = split_params(cfg, params, cfg.default_cut_units)
    h = client_forward(cfg, cp, batch)
    x = h["h"]
    if cfg.is_encoder_decoder:
        raise NotImplementedError("use prefill/decode for enc-dec logits")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _ = _unit_scan(cfg, sp["units"], x, positions, h.get("ctx"),
                      cfg.block_pattern, causal=True)
    x = apply_norm(cfg, sp["final_norm"], x)
    head = sp.get("lm_head", params.get("lm_head"))
    if head is None:
        head = params["embed"].T
    return x @ head


# ===========================================================================
# serving: cache init / prefill / decode
# ===========================================================================

def _block_cache_init(cfg: ModelConfig, btype: str, batch: int, seq_len: int,
                      n_ctx: int):
    if btype in ("attn", "dec"):
        c = (attn.mla_init_cache(cfg, batch, seq_len) if cfg.attn_impl == "mla"
             else attn.gqa_init_cache(cfg, batch, seq_len))
        if btype == "dec":
            return {"self": c, "cross": attn.xattn_init_cache(cfg, batch, n_ctx)}
        return c
    if btype == "xattn":
        return attn.xattn_init_cache(cfg, batch, n_ctx)
    if btype == "mamba":
        return ssm.mamba_init_state(cfg, batch)
    if btype == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    if btype == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(btype)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    n_ctx = cfg.n_image_tokens or cfg.n_audio_frames or 1
    pattern = ("dec",) if cfg.is_encoder_decoder else cfg.block_pattern
    n_units = cfg.n_layers if cfg.is_encoder_decoder else cfg.n_units

    unit_cache = {f"b{j}": _block_cache_init(cfg, bt, batch, seq_len, n_ctx)
                  for j, bt in enumerate(pattern)}
    stacked = jax.tree.map(lambda a: jnp.zeros((n_units,) + a.shape, a.dtype),
                           unit_cache)

    def patch(tree):   # mlstm/slstm 'm' stabilizers must start at -inf-ish
        if isinstance(tree, dict):
            return {k: (jnp.full(v.shape, -1e30, v.dtype)
                        if k == "m" and not isinstance(v, dict) else patch(v))
                    for k, v in tree.items()}
        return tree
    return patch(stacked)


def _decode_block(cfg: ModelConfig, p: Params, btype: str, x, cache, pos, ctx):
    h = apply_norm(cfg, p["norm1"], x)
    if btype == "attn":
        if cfg.attn_impl == "mla":
            y, cache = attn.mla_decode(cfg, p["core"], h, cache, pos)
        else:
            y, cache = attn.gqa_decode(cfg, p["core"], h, cache, pos)
        x = x + y
    elif btype == "xattn":
        x = x + attn.xattn_decode(cfg, p["core"], h, cache, gated=True)
    elif btype == "mamba":
        y, cache = ssm.mamba_decode(cfg, p["core"], h, cache)
        x = x + y
    elif btype == "mlstm":
        y, cache = ssm.mlstm_decode(cfg, p["core"], h, cache)
        x = x + y
    elif btype == "slstm":
        y, cache = ssm.slstm_decode(cfg, p["core"], h, cache)
        x = x + y
    elif btype == "dec":
        if cfg.attn_impl == "mla":
            y, sc = attn.mla_decode(cfg, p["core"], h, cache["self"], pos)
        else:
            y, sc = attn.gqa_decode(cfg, p["core"], h, cache["self"], pos)
        x = x + y
        hx = apply_norm(cfg, p["norm_x"], x)
        x = x + attn.xattn_decode(cfg, p["xattn"], hx, cache["cross"])
        cache = {"self": sc, "cross": cache["cross"]}
    if _has_ffn(cfg, btype):
        h2 = apply_norm(cfg, p["norm2"], x)
        if "router" in p["ffn"]:
            y, _ = moe_lib.apply_moe(cfg, p["ffn"], h2)
        else:
            y = apply_mlp(cfg, p["ffn"], h2)
        x = x + y
    return x, cache


def decode_step(cfg: ModelConfig, params: Params, token, cache, pos):
    """One-token decode. token: (B,1) i32; pos: scalar i32 absolute position.
    Returns (logits (B,1,V), new_cache)."""
    pattern = ("dec",) if cfg.is_encoder_decoder else cfg.block_pattern
    units = params["units"]
    x = _embed_tokens(cfg, params, token)

    def body(x, xs):
        unit_p, unit_c = xs
        new_c = {}
        for j, bt in enumerate(pattern):
            x, c = _decode_block(cfg, unit_p[f"b{j}"], bt, x, unit_c[f"b{j}"],
                                 pos, None)
            new_c[f"b{j}"] = c
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (units, cache))
    x = apply_norm(cfg, params["final_norm"], x)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x @ head, new_cache


# ---- prefill ---------------------------------------------------------------

def _prefill_block(cfg: ModelConfig, p: Params, btype: str, x, positions, ctx,
                   seq_len: int):
    """Full-sequence pass that also materializes the decode cache."""
    from repro.models.attention import gqa_cache_len
    h = apply_norm(cfg, p["norm1"], x)
    B, S, _ = x.shape
    if btype in ("attn", "dec"):
        core = p["core"]
        if cfg.attn_impl == "mla":
            y = attn.mla_attention(cfg, core, h, positions, causal=True)
            kv_a = h @ core["wkv_a"]
            from repro.models.layers import rms_norm_simple, apply_rope
            r = cfg.kv_lora_rank
            c_kv = rms_norm_simple(kv_a[..., :r], core["kv_norm"])
            k_rope = apply_rope(kv_a[:, None, :, r:], positions[:, None, :],
                                cfg.rope_theta)[:, 0]
            cache = {"c_kv": _right_pad(c_kv, seq_len, 1),
                     "k_rope": _right_pad(k_rope, seq_len, 1)}
        else:
            y = attn.gqa_attention(cfg, core, h, positions, causal=True)
            from repro.models.layers import rms_norm_simple, apply_rope
            Hkv, dh = cfg.n_kv_heads, cfg.d_head
            k = (h @ core["wk"]).reshape(B, S, Hkv, dh)
            v = (h @ core["wv"]).reshape(B, S, Hkv, dh)
            if cfg.qk_norm:
                k = rms_norm_simple(k, core["k_norm"])
            k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
            v = v.swapaxes(1, 2)
            Sc = gqa_cache_len(cfg, max(seq_len, S))
            if Sc < S:     # sliding-window ring: keep last Sc positions
                pos_keep = jnp.arange(S - Sc, S)
                slots = pos_keep % Sc
                ck = jnp.zeros((B, Hkv, Sc, dh), k.dtype).at[:, :, slots].set(
                    k[:, :, pos_keep])
                cv = jnp.zeros((B, Hkv, Sc, dh), v.dtype).at[:, :, slots].set(
                    v[:, :, pos_keep])
            else:
                ck, cv = _right_pad(k, Sc, 2), _right_pad(v, Sc, 2)
            cache = {"k": ck, "v": cv}
        if btype == "dec":
            xout = x + y
            hx = apply_norm(cfg, p["norm_x"], xout)
            xout = xout + attn.cross_attention(cfg, p["xattn"], hx, ctx)
            cache = {"self": cache,
                     "cross": attn.xattn_fill_cache(cfg, p["xattn"], ctx)}
        else:
            xout = x + y
    elif btype == "xattn":
        xout = x + attn.cross_attention(cfg, p["core"], h, ctx, gated=True)
        cache = attn.xattn_fill_cache(cfg, p["core"], ctx)
    elif btype == "mamba":
        y, cache = ssm.mamba_forward(cfg, p["core"], h)
        xout = x + y
    elif btype == "mlstm":
        y, cache = ssm.mlstm_forward(cfg, p["core"], h)
        xout = x + y
    elif btype == "slstm":
        y, cache = ssm.slstm_forward(cfg, p["core"], h)
        xout = x + y
    else:
        raise ValueError(btype)
    if _has_ffn(cfg, btype):
        h2 = apply_norm(cfg, p["norm2"], xout)
        if "router" in p["ffn"]:
            y2, _ = moe_lib.apply_moe(cfg, p["ffn"], h2)
        else:
            y2 = apply_mlp(cfg, p["ffn"], h2)
        xout = xout + y2
    return xout, cache


def _right_pad(a, target: int, axis: int):
    pad = target - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def prefill(cfg: ModelConfig, params: Params, batch, *, cache_len: int = 0):
    """Run the full prompt, building the decode cache.
    Returns (logits_last (B,1,V), cache)."""
    if cfg.is_encoder_decoder:
        x = batch["frames"] @ params["audio_proj"]
        B, F, _ = x.shape
        pos_e = jnp.broadcast_to(jnp.arange(F), (B, F))
        x, _ = _unit_scan(cfg, params["enc_units"], x, pos_e, None, ("attn",),
                          causal=False)
        enc_out = apply_norm(cfg, params["enc_norm"], x)
        tokens = batch["tokens"]
        ctx = enc_out
        pattern = ("dec",)
        units = params["units"]
    else:
        tokens = batch["tokens"]
        ctx = _context_stream(cfg, params, batch)
        pattern = cfg.block_pattern
        units = params["units"]
    B, S = tokens.shape
    seq_len = max(cache_len, S)
    x = _embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, unit_p):
        caches = {}
        for j, bt in enumerate(pattern):
            x, c = _prefill_block(cfg, unit_p[f"b{j}"], bt, x, positions, ctx,
                                  seq_len)
            caches[f"b{j}"] = c
        return x, caches

    x, cache = jax.lax.scan(body, x, units)
    x = apply_norm(cfg, params["final_norm"], x[:, -1:])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x @ head, cache
