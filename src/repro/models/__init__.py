"""Model definitions: pure-functional JAX transformers (+SSM/hybrid/enc-dec).

Public API:
    init_params(cfg, key)             -> param pytree (stacked units, scan-ready)
    loss_fn(cfg, params, batch)       -> scalar CE loss
    split_params(cfg, params, cut)    -> (client_params, server_params)
    client_forward(cfg, cp, batch)    -> cut-layer embedding h
    server_forward(cfg, sp, h, batch) -> scalar loss
    init_cache(cfg, batch, seq_len)   -> decode cache pytree
    prefill(cfg, params, batch)       -> (logits_last, cache)
    decode_step(cfg, params, token, cache, pos) -> (logits, cache)
"""
from repro.models.transformer import (
    init_params,
    loss_fn,
    logits_fn,
    split_params,
    merge_params,
    client_forward,
    server_forward,
    forward_from_cut,
    init_cache,
    prefill,
    decode_step,
    param_count,
    split_dims,
    untie_params,
)
