"""Attention: GQA (full / causal / sliding-window, optional qk-norm),
MLA (DeepSeek-V2, with absorbed-weight compressed-cache decode), and
cross-attention (whisper decoder / llama-vision image layers).

Decode paths operate on a KV cache laid out ``(B, H_kv, S_cache, d)`` (GQA)
or ``(B, S_cache, r)`` (MLA compressed). Softmax reductions run over the
cache-sequence dim; when that dim is sharded (flash-decoding style), GSPMD
lowers the max/sum reductions to all-reduces — the partial-softmax merge is
expressed by the reduction structure, not hand-written collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm_simple

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 8)
    if cfg.attn_impl == "mla":
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wkv_a": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
            "wkv_b": dense_init(ks[3], (cfg.kv_lora_rank,
                                        cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)), dtype),
            "wo": dense_init(ks[4], (cfg.n_heads * cfg.v_head_dim, d), dtype),
        }
        if cfg.q_lora_rank > 0:
            p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), dtype)
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), jnp.float32)
            p["wq_b"] = dense_init(ks[1], (cfg.q_lora_rank, cfg.n_heads * qk_dim), dtype)
        else:
            p["wq"] = dense_init(ks[0], (d, cfg.n_heads * qk_dim), dtype)
        return p
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * dh), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * dh), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * dh), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * dh, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def init_xattn(cfg: ModelConfig, key):
    """Cross-attention (no rope; full MHA over a context stream)."""
    dtype = jnp.dtype(cfg.dtype)
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * dh), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_heads * dh), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_heads * dh), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * dh, d), dtype),
        "gate": jnp.zeros((), jnp.float32),   # llama-vision tanh gate
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _mask(S_q: int, S_k: int, causal: bool, window: int):
    iq = jnp.arange(S_q)[:, None]
    jk = jnp.arange(S_k)[None, :]
    ok = jnp.ones((S_q, S_k), bool)
    if causal:
        ok &= jk <= iq
    if window > 0:
        ok &= (iq - jk) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# GQA full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def gqa_attention(cfg: ModelConfig, p, x, positions, *, causal: bool = True):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // Hkv
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)  # (B,H,S,dh)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)  # (B,Hkv,S,dh)
    q = q.reshape(B, Hkv, G, S, dh)
    v = v.swapaxes(1, 2)                                                     # (B,Hkv,S,dh)
    w = cfg.sliding_window
    if causal and w > 0 and S > 2 * w and S % w == 0:
        return _banded_swa(cfg, p, q, k, v, B, S, H, Hkv, G, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + _mask(S, S, causal, cfg.sliding_window)[None, None,
                                                             None]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,bktd->bskgd", probs, v).reshape(B, S, H * dh)
    return out @ p["wo"]


def _banded_swa(cfg: ModelConfig, p, q, k, v, B, S, H, Hkv, G, dh):
    """Block-sparse sliding-window attention: with window w and w-sized
    blocks, query block i only sees key blocks {i-1, i}. Exact equivalent
    of the masked full computation, with O(S·2w) scores instead of O(S²)
    (the jnp-path analogue of the flash kernel's block skipping)."""
    w = cfg.sliding_window
    nb = S // w
    qb = q.reshape(B, Hkv, G, nb, w, dh)
    kb = k.reshape(B, Hkv, nb, w, dh)
    vb = v.reshape(B, Hkv, nb, w, dh)
    zpad = jnp.zeros((B, Hkv, 1, w, dh), k.dtype)
    kprev = jnp.concatenate([zpad, kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([zpad, vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([kprev, kb], axis=3)            # (B,Hkv,nb,2w,dh)
    v2 = jnp.concatenate([vprev, vb], axis=3)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bkgnrd,bkntd->bkgnrt", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    # in-band mask: key col c (0..2w-1) is visible to query row r iff
    # r < c <= r + w  (i.e. causal + within window), plus block-0 has no
    # predecessor block.
    r = jnp.arange(w)[:, None]
    c = jnp.arange(2 * w)[None, :]
    ok = (c <= r + w) & (c > r)
    first = jnp.arange(nb)[:, None, None] > 0
    ok = ok[None] & (first | (c[None] >= w))             # (nb, w, 2w)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgnrt,bkntd->bnrkgd", probs, v2)
    out = out.reshape(B, S, H * dh)
    return out @ p["wo"]


def cross_attention(cfg: ModelConfig, p, x, ctx, *, gated: bool = False):
    """x: (B, S, D) queries; ctx: (B, T, D) context (image/encoder stream)."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    T = ctx.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, dh).swapaxes(1, 2)
    k = (ctx @ p["wk"]).reshape(B, T, H, dh).swapaxes(1, 2)
    v = (ctx @ p["wv"]).reshape(B, T, H, dh).swapaxes(1, 2)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bhtd->bshd", probs, v).reshape(B, S, H * dh)
    out = out @ p["wo"]
    if gated:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# GQA decode (single token, ring-buffered KV cache for SWA)
# ---------------------------------------------------------------------------

def gqa_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window > 0 else seq_len


def gqa_init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    Sc = gqa_cache_len(cfg, seq_len)
    dtype = jnp.dtype(cfg.dtype)
    shape = (batch, cfg.n_kv_heads, Sc, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(cfg: ModelConfig, p, x, cache, pos):
    """x: (B, 1, D); pos: scalar int32 absolute position. Returns (out, cache)."""
    B, _, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // Hkv
    Sc = cache["k"].shape[2]
    q = (x @ p["wq"]).reshape(B, H, 1, dh)
    k = (x @ p["wk"]).reshape(B, Hkv, 1, dh)
    v = (x @ p["wv"]).reshape(B, Hkv, 1, dh)
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posb[:, None, :], cfg.rope_theta).reshape(B, Hkv, G, dh)
    k = apply_rope(k, posb[:, None, :], cfg.rope_theta)
    slot = jnp.where(cfg.sliding_window > 0, pos % Sc, pos).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bktd->bkgt", q, ck,
                        preferred_element_type=jnp.float32) * scale
    # validity: slots written so far (ring buffer fills monotonically)
    valid = jnp.arange(Sc) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, cv).reshape(B, 1, H * dh)
    return out @ p["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# cross-attention decode cache (static context — filled once at prefill)
# ---------------------------------------------------------------------------

def xattn_init_cache(cfg: ModelConfig, batch: int, n_ctx: int):
    dtype = jnp.dtype(cfg.dtype)
    shape = (batch, cfg.n_heads, n_ctx, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def xattn_fill_cache(cfg: ModelConfig, p, ctx):
    B, T, _ = ctx.shape
    H, dh = cfg.n_heads, cfg.d_head
    k = (ctx @ p["wk"]).reshape(B, T, H, dh).swapaxes(1, 2)
    v = (ctx @ p["wv"]).reshape(B, T, H, dh).swapaxes(1, 2)
    return {"k": k, "v": v}


def xattn_decode(cfg: ModelConfig, p, x, cache, *, gated: bool = False):
    B, _, D = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, 1, H, dh).swapaxes(1, 2)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, cache["k"],
                        preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bhtd->bshd", probs, cache["v"]).reshape(B, 1, H * dh)
    out = out @ p["wo"]
    if gated:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(cfg: ModelConfig, p, x):
    B, S = x.shape[0], x.shape[1]
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        q = rms_norm_simple(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    return q.reshape(B, S, cfg.n_heads, qk_dim)


def mla_attention(cfg: ModelConfig, p, x, positions, *, causal: bool = True):
    """Full-sequence MLA. x: (B, S, D)."""
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rope_d, vd, r = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                           cfg.v_head_dim, cfg.kv_lora_rank)
    q = _mla_q(cfg, p, x)                                   # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None, :],
                        cfg.rope_theta).swapaxes(1, 2)      # (B,S,H,rope)
    kv_a = x @ p["wkv_a"]                                   # (B,S,r+rope)
    c_kv = rms_norm_simple(kv_a[..., :r], p["kv_norm"])
    k_rope = apply_rope(kv_a[:, None, :, r:], positions[:, None, :],
                        cfg.rope_theta)[:, 0]               # (B,S,rope)
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    scale = 1.0 / jnp.sqrt(nope + rope_d).astype(jnp.float32)
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,btd->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    scores = scores + _mask(S, S, causal, 0)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, H * vd)
    return out @ p["wo"]


def mla_init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    dtype = jnp.dtype(cfg.dtype)
    return {"c_kv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_dim), dtype)}


def mla_decode(cfg: ModelConfig, p, x, cache, pos):
    """Absorbed-weight MLA decode over the compressed cache.

    score_h = (q_nope_h W_kb_h) . c_kv + q_rope_h . k_rope
    out_h   = (probs @ c_kv) W_vb_h
    The per-token cache holds only r + rope_d values — MLA's memory win.
    """
    B, _, D = x.shape
    H = cfg.n_heads
    nope, rope_d, vd, r = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                           cfg.v_head_dim, cfg.kv_lora_rank)
    q = _mla_q(cfg, p, x)[:, 0]                             # (B,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    posb = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope[:, :, None, :], posb[:, None, :],
                        cfg.rope_theta)[:, :, 0]            # (B,H,rope)
    kv_a = (x @ p["wkv_a"])[:, 0]                           # (B, r+rope)
    c_new = rms_norm_simple(kv_a[:, :r], p["kv_norm"])
    k_rope_new = apply_rope(kv_a[:, None, None, r:], posb[:, None, :],
                            cfg.rope_theta)[:, 0]           # (B,1,rope)
    c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_new[:, None, :],
                                           (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new,
                                           (0, pos, 0))
    wkv_b = p["wkv_b"].reshape(r, H, nope + vd)
    w_kb, w_vb = wkv_b[..., :nope], wkv_b[..., nope:]
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, w_kb)
    scale = 1.0 / jnp.sqrt(nope + rope_d).astype(jnp.float32)
    scores = (jnp.einsum("bhr,btr->bht", q_abs, c_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhn,btn->bht", q_rope, r_cache,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cache["c_kv"].shape[1]) <= pos
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bht,btr->bhr", probs, c_cache)       # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", o_lat, w_vb).reshape(B, 1, H * vd)
    return out @ p["wo"], {"c_kv": c_cache, "k_rope": r_cache}
