from repro.data.synthetic import SyntheticLM, SyntheticSentiment
from repro.data.partition import dirichlet_partition
from repro.data.loader import FederatedLoader, make_client_batches
