"""Federated data partitioning: Dirichlet non-IID class allocation (the
standard FL heterogeneity protocol; paper §5 trains 10 clients with 50%
participation under heterogeneous data)."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 1
                        ) -> List[np.ndarray]:
    """Split sample indices among clients with Dir(alpha) class proportions.

    Returns a list of index arrays (disjoint, covering all samples).
    Smaller alpha = more heterogeneity. Guarantees >= min_per_client.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    buckets: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
        for m, part in enumerate(np.split(idx, cuts)):
            buckets[m].extend(part.tolist())
    # rebalance empties
    sizes = [len(b) for b in buckets]
    for m in range(n_clients):
        while len(buckets[m]) < min_per_client:
            donor = int(np.argmax([len(b) for b in buckets]))
            buckets[m].append(buckets[donor].pop())
    out = [np.asarray(sorted(b), np.int64) for b in buckets]
    assert sum(len(b) for b in out) == len(labels)
    return out


def heterogeneity_epsilon(class_fracs: np.ndarray) -> float:
    """Empirical proxy for Assumption 4.3's ε: max TV distance between a
    client's class distribution and the global one."""
    global_p = class_fracs.mean(0)
    return float(np.abs(class_fracs - global_p[None]).sum(-1).max() / 2)
