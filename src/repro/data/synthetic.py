"""Synthetic datasets (offline container — no downloads).

SyntheticLM        : a seeded order-1 Markov language with Zipfian unigrams —
                     learnable structure (bigram statistics) so training
                     losses genuinely decrease; deterministic per (seed,
                     index), so restarts resample identical data.
SyntheticSentiment : the SST-2 stand-in for the paper's LLM experiments —
                     sequences carry planted positive/negative marker tokens
                     whose balance determines a label verbalized as the final
                     token; per-class generation supports Dirichlet non-IID
                     partitioning.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    branching: int = 4          # successors per token -> learnable bigrams

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipfian unigram over vocab; each token gets `branching` successors
        ranks = np.arange(1, self.vocab_size + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.successors = rng.integers(0, self.vocab_size,
                                       size=(self.vocab_size, self.branching))

    def sample(self, index: int) -> np.ndarray:
        """One (seq_len+1,) token stream, deterministic in (seed, index)."""
        rng = np.random.default_rng((self.seed, index))
        out = np.empty(self.seq_len + 1, np.int32)
        out[0] = rng.choice(self.vocab_size, p=self.unigram)
        picks = rng.integers(0, self.branching, size=self.seq_len)
        resets = rng.random(self.seq_len) < 0.05     # occasional re-draws
        fresh = rng.choice(self.vocab_size, size=self.seq_len, p=self.unigram)
        for t in range(self.seq_len):
            out[t + 1] = (fresh[t] if resets[t]
                          else self.successors[out[t], picks[t]])
        return out

    def batch(self, indices) -> dict:
        toks = np.stack([self.sample(int(i)) for i in indices])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class SyntheticSentiment:
    """Binary 'sentiment': marker tokens 0..9 are negative cues, 10..19
    positive; the label token (vocab-2 = NEG, vocab-1 = POS) is the final
    token; loss is next-token CE, so accuracy = P(correct label token)."""
    vocab_size: int
    seq_len: int
    seed: int = 0
    n_classes: int = 2

    def sample(self, index: int, label: int | None = None):
        rng = np.random.default_rng((self.seed, index))
        if label is None:
            label = int(rng.integers(0, self.n_classes))
        body = rng.integers(20, self.vocab_size - 2, size=self.seq_len)
        # plant class markers with majority agreeing with the label
        n_mark = max(2, self.seq_len // 8)
        pos = rng.choice(self.seq_len - 1, size=n_mark, replace=False)
        agree = rng.random(n_mark) < 0.9
        cue = np.where(agree == (label == 1),
                       rng.integers(10, 20, n_mark),   # positive cues
                       rng.integers(0, 10, n_mark))    # negative cues
        body[pos] = cue
        body[-1] = self.vocab_size - 2 + label
        return body.astype(np.int32), label

    def batch(self, indices, labels=None) -> dict:
        rows, ys = [], []
        for j, i in enumerate(indices):
            r, y = self.sample(int(i), None if labels is None else int(labels[j]))
            rows.append(r)
            ys.append(y)
        toks = np.stack(rows)
        labels_arr = np.full_like(toks, -100)          # only score the label slot
        labels_arr[:, :-1] = toks[:, 1:]
        return {"tokens": toks, "labels": labels_arr,
                "class": np.asarray(ys, np.int32)}

    def accuracy(self, logits_last, ys) -> float:
        """logits_last: (B, V) at the position predicting the label token."""
        pred = logits_last[:, self.vocab_size - 2:self.vocab_size].argmax(-1)
        return float((pred == ys).mean())
