"""Sharded host loader. Stateless indexing: batch contents are a pure
function of (seed, round, client) so checkpoint restarts resume the exact
data order with no loader state to save. Device placement uses
NamedSharding when a mesh is given (each host materializes only what lands
on its addressable devices in a real multi-host run; here single-host)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def make_client_batches(dataset, client_indices: List[np.ndarray],
                        round_idx: int, batch_per_client: int,
                        seed: int = 0) -> Dict[str, np.ndarray]:
    """Stack per-client batches -> leaves with leading M dim.

    A client whose index pool is empty (possible when a sparse Dirichlet
    partition is built without the min_per_client rebalance) samples from
    the union of all clients' pools instead of crashing in rng.choice(0);
    if every pool is empty there is no data at all and we raise."""
    nonempty = [np.asarray(p) for p in client_indices if len(p)]
    if not nonempty:
        raise ValueError("make_client_batches: all client index pools are "
                         "empty — no data to sample")
    global_pool = (np.concatenate(nonempty) if len(nonempty) <
                   len(client_indices) else None)
    outs = []
    for m, idx_pool in enumerate(client_indices):
        rng = np.random.default_rng((seed, round_idx, m))
        pool = np.asarray(idx_pool) if len(idx_pool) else global_pool
        take = rng.choice(len(pool), size=batch_per_client,
                          replace=len(pool) < batch_per_client)
        outs.append(dataset.batch(pool[take]))
    return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


@dataclasses.dataclass
class FederatedLoader:
    dataset: object
    client_indices: List[np.ndarray]
    batch_per_client: int
    seed: int = 0
    mesh: Optional[jax.sharding.Mesh] = None
    batch_spec: Optional[P] = None        # e.g. P('data') on the M dim

    def round_batch(self, round_idx: int):
        host = make_client_batches(self.dataset, self.client_indices,
                                   round_idx, self.batch_per_client, self.seed)
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        spec = self.batch_spec if self.batch_spec is not None else P("data")
        sh = NamedSharding(self.mesh, spec)
        return {k: jax.device_put(v, sh) for k, v in host.items()}
