"""Sharded host loader. Stateless indexing: batch contents are a pure
function of (seed, round, client) so checkpoint restarts resume the exact
data order with no loader state to save — which also makes subset staging
exact: materializing only the K clients that start a sparse version draws
the same rows those clients would get in a fleet-width gather. Device
placement uses NamedSharding when a mesh is given (each host materializes
only what lands on its addressable devices in a real multi-host run; here
single-host)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.obs.trace import span


def client_pools(client_indices: List[np.ndarray]) -> List[np.ndarray]:
    """Resolve per-client index pools once (the empty-pool fallback hoisted
    out of the per-round path).

    A client whose index pool is empty (possible when a sparse Dirichlet
    partition is built without the min_per_client rebalance) samples from
    the union of all clients' pools instead of crashing in rng.choice(0);
    if every pool is empty there is no data at all and we raise. The
    common all-nonempty case never concatenates."""
    pools = [np.asarray(p) for p in client_indices]
    nonempty = [p for p in pools if p.size]
    if not nonempty:
        raise ValueError("client_pools: all client index pools are "
                         "empty — no data to sample")
    if len(nonempty) < len(pools):
        global_pool = np.concatenate(nonempty)
        pools = [p if p.size else global_pool for p in pools]
    return pools


def make_client_batches(dataset, client_indices: List[np.ndarray],
                        round_idx: int, batch_per_client: int,
                        seed: int = 0, *,
                        client_ids: Optional[Sequence[int]] = None,
                        pools: Optional[List[np.ndarray]] = None,
                        ) -> Dict[str, np.ndarray]:
    """Stack per-client batches -> leaves with leading client dim.

    ``client_ids`` selects an explicit subset: only those rows are
    materialized, in the given order — (K, ...) instead of (M, ...). The
    per-client RNG is keyed on (seed, round, client-id), so the subset
    path is bit-exact against indexing the fleet-width stack: row j equals
    full[client_ids[j]] for the same (seed, round).

    ``pools`` supplies pre-resolved index pools (see ``client_pools``) so
    repeated calls skip the per-client np.asarray pass; when omitted they
    are resolved here.
    """
    if pools is None:
        pools = client_pools(client_indices)
    ids = range(len(pools)) if client_ids is None else client_ids
    outs = []
    for m in ids:
        m = int(m)
        rng = np.random.default_rng((seed, round_idx, m))
        pool = pools[m]
        take = rng.choice(pool.size, size=batch_per_client,
                          replace=pool.size < batch_per_client)
        outs.append(dataset.batch(pool[take]))
    return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


@dataclasses.dataclass
class FederatedLoader:
    dataset: object
    client_indices: List[np.ndarray]
    batch_per_client: int
    seed: int = 0
    mesh: Optional[jax.sharding.Mesh] = None
    batch_spec: Optional[P] = None        # e.g. P('data') on the M dim
    _pools: Optional[List[np.ndarray]] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def pools(self) -> List[np.ndarray]:
        """Per-client index pools, resolved once and cached."""
        if self._pools is None:
            self._pools = client_pools(self.client_indices)
        return self._pools

    def round_batch(self, round_idx: int):
        host = make_client_batches(self.dataset, self.client_indices,
                                   round_idx, self.batch_per_client,
                                   self.seed, pools=self.pools)
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        spec = self.batch_spec if self.batch_spec is not None else P("data")
        sh = NamedSharding(self.mesh, spec)
        return {k: jax.device_put(v, sh) for k, v in host.items()}

    def subset_batch(self, round_idx: int,
                     client_ids: Sequence[int]) -> Dict[str, np.ndarray]:
        """(K, ...) host rows for exactly ``client_ids``, bit-exact with
        ``round_batch(round_idx)[client_ids]`` — the sparse engine's O(K)
        staging path (device placement is the engine's concern: sparse
        chunks are stacked host-side first)."""
        with span("loader.subset_batch", round=round_idx,
                  k=len(client_ids)):
            return make_client_batches(self.dataset, self.client_indices,
                                       round_idx, self.batch_per_client,
                                       self.seed, client_ids=client_ids,
                                       pools=self.pools)
