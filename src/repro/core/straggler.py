"""Straggler system model: device heterogeneity, wall-clock simulation,
deadline-based participation, and the paper's τ-planner.

The paper (§5) simulates heterogeneity by sampling per-client computation
time from an exponential distribution; Eq. 12 shows that with
τ = t_straggler / t_server the total time T₀·t_straggler/τ = T₀·t_server
becomes independent of the straggler. This module reproduces that system
model and exposes it to the trainer as *data* (delays, masks) — the jit'd
round math never blocks on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.population import (AvailRow, ClientPopulation, Cohort,
                                   DelayModel, parse_population)

__all__ = [
    "DelayModel", "Cohort", "ClientPopulation", "parse_population",
    "AvailRow", "Schedule", "SparseSchedule", "make_schedule",
    "make_schedule_stream", "make_sparse_schedule", "participation_mask",
    "deadline_mask", "median_fresh_mask", "plan_tau",
    "round_time_mu_splitfed", "round_time_vanilla", "round_time_gas",
    "round_time_local_only", "WallClock", "simulate_total_time",
]


def participation_mask(rng: np.random.Generator, n_clients: int,
                       fraction: float) -> np.ndarray:
    """Random partial participation (paper: 50%). Always >=1 active."""
    k = max(1, int(round(fraction * n_clients)))
    idx = rng.choice(n_clients, size=k, replace=False)
    m = np.zeros((n_clients,), np.float32)
    m[idx] = 1.0
    return m


def deadline_mask(delays: np.ndarray, deadline: float) -> np.ndarray:
    """Drop clients slower than the deadline (straggler mitigation knob)."""
    if deadline <= 0:
        return np.ones_like(delays, np.float32)
    m = (delays <= deadline).astype(np.float32)
    if m.sum() == 0:                       # never drop everyone
        m[np.argmin(delays)] = 1.0
    return m


def median_fresh_mask(delays: np.ndarray) -> np.ndarray:
    """GAS freshness rule (Fig. 2 protocol): clients at or below the
    per-round median delay deliver in time; the rest are served from the
    stale activation buffer. delays: (M,) or (R, M); returns same shape."""
    d = np.asarray(delays, np.float64)
    med = np.median(d, axis=-1, keepdims=True)
    return (d <= med).astype(np.float32)


def plan_tau(t_straggler: float, t_server: float, tau_max: int = 64) -> int:
    """Paper Eq. 12: τ* = t_straggler / t_server (clipped, >=1)."""
    return int(np.clip(round(t_straggler / max(t_server, 1e-9)), 1, tau_max))


# ---------------------------------------------------------------------------
# precomputed schedules: the system model as (R, M) data
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """The full system-model trace for an R-round run, precomputed on host.

    The engine (core/engine.py) scans these rows as *data* — the jit'd
    round math never blocks on the host simulator. All arrays are (R, M):

      delays         per-round client compute times (seconds, simulated)
      participation  0/1 availability·participation draw (per cohort)
      deadline       0/1 deadline survivors (all-ones when deadline <= 0)
      masks          participation * deadline — what the round consumes
      fresh_median   GAS freshness rule (<= per-round median delay)

    t_server / t_gen / t_comm are the scalar wall-clock model knobs; the
    per-algorithm round-time models read them through this object.
    ``t_comm_scale`` ((M,), optional) carries per-client uplink multipliers
    from a heterogeneous population — ``comm_for(mask)`` charges the round
    the slowest *active* link; ``population`` records the fleet spec the
    schedule was sampled from.
    """
    delays: np.ndarray
    participation: np.ndarray
    deadline: np.ndarray
    masks: np.ndarray
    fresh_median: np.ndarray
    seed: int = 0
    t_server: float = 0.1
    t_gen: float = 0.0
    t_comm: float = 0.0
    t_comm_scale: Optional[np.ndarray] = None
    population: Optional[ClientPopulation] = None

    @property
    def n_rounds(self) -> int:
        return self.delays.shape[0]

    @property
    def n_clients(self) -> int:
        return self.delays.shape[1]

    def row(self, r: int) -> Tuple[np.ndarray, np.ndarray]:
        """(delays, mask) for absolute round r (cyclic past n_rounds)."""
        i = r % self.n_rounds
        return self.delays[i], self.masks[i]

    def comm_for(self, mask: np.ndarray) -> float:
        """Per-round communication time under ``mask``: t_comm scaled by the
        slowest active client's uplink (uniform fleets: just t_comm)."""
        if self.t_comm_scale is None or self.t_comm == 0.0:
            return self.t_comm
        active = self.t_comm_scale[np.asarray(mask) > 0]
        return self.t_comm * (float(active.max()) if active.size else 1.0)


def make_schedule(seed: int, n_rounds: int, n_clients: Optional[int] = None,
                  *,
                  population: Optional[ClientPopulation] = None,
                  delay_model: Optional[DelayModel] = None,
                  straggler_scale: float = 0.0,
                  participation: float = 1.0,
                  deadline: float = 0.0,
                  t_server: float = 0.1,
                  t_gen: float = 0.0,
                  t_comm: float = 0.0) -> Schedule:
    """Precompute the whole system-model trace as stacked (R, M) arrays.

    The fleet is a ClientPopulation: delays, availability (iid draws or
    Markov up/down chains), and participation are sampled per cohort. The
    legacy scalar knobs (``delay_model``/``straggler_scale``/
    ``participation``) are the deprecated single-cohort shorthand — they
    resolve to ``ClientPopulation.single`` and, because the per-cohort
    sampler consumes the RNG in the historical order (delay draw first,
    only when stochastic, then the participation draw, cohort by cohort),
    a single-iid-cohort population reproduces the old per-round scalar
    path bit-for-bit (tests/test_engine.py + tests/test_population.py pin
    this). Deterministic in (seed, n_rounds, population, knobs).
    """
    population = _resolve_population(population, n_clients, delay_model,
                                     straggler_scale, participation)
    M = population.n_clients
    chunks = list(make_schedule_stream(
        seed, n_rounds, population=population, deadline=deadline,
        t_server=t_server, t_gen=t_gen, t_comm=t_comm))

    def cat(field, dtype, width=M):
        if not chunks:
            return np.zeros((0, width), dtype)
        return np.concatenate([getattr(c, field) for c in chunks])

    return Schedule(delays=cat("delays", np.float64),
                    participation=cat("participation", np.float32),
                    deadline=cat("deadline", np.float32),
                    masks=cat("masks", np.float32),
                    fresh_median=cat("fresh_median", np.float32),
                    seed=seed, t_server=t_server, t_gen=t_gen, t_comm=t_comm,
                    t_comm_scale=(None if population.uniform_comm
                                  else population.t_comm_scales()),
                    population=population)


def _resolve_population(population, n_clients, delay_model, straggler_scale,
                        participation) -> ClientPopulation:
    if population is None:
        if n_clients is None:
            raise ValueError("make_schedule: pass n_clients or population")
        return ClientPopulation.single(
            n_clients,
            delay=delay_model or DelayModel(base=1.0, scale=straggler_scale),
            participation=participation)
    if n_clients is not None and n_clients != population.n_clients:
        raise ValueError(f"n_clients={n_clients} != population's "
                         f"{population.n_clients}")
    return population


def make_schedule_stream(seed: int, n_rounds: int,
                         n_clients: Optional[int] = None,
                         *,
                         population: Optional[ClientPopulation] = None,
                         delay_model: Optional[DelayModel] = None,
                         straggler_scale: float = 0.0,
                         participation: float = 1.0,
                         deadline: float = 0.0,
                         t_server: float = 0.1,
                         t_gen: float = 0.0,
                         t_comm: float = 0.0,
                         chunk_rounds: int = 64,
                         lazy: bool = False):
    """Stream the system-model trace as Schedule chunks of ``chunk_rounds``
    rows each (the last chunk may be shorter).

    One shared PopulationSampler draws rows in round order — delay row
    first, then participation, cohort by cohort — so the chunked stream
    consumes the RNG exactly like the monolithic loop: concatenating the
    yielded chunks reproduces make_schedule(...) bit-for-bit. The pinning
    is structural: make_schedule IS the concatenation of this generator
    (and tests/test_population.py cross-checks odd chunk sizes). Each
    chunk is a full Schedule carrying the shared scalar knobs, so row
    consumers (the sparse TimelineStream, bench_timeline) can work on
    fleets whose full (R, M) trace would not fit on the host.

    ``lazy=True`` switches to the streaming mask protocol: yields ONE
    SparseSchedule covering all rounds — per-cohort AvailRows and keyed
    on-demand delays, nothing materialized at all, so million-client
    fleets never densify (not RNG-compatible with the dense draw; see
    SparseSchedule). Requires deadline <= 0 (a deadline needs the full
    delay row by definition).
    """
    population = _resolve_population(population, n_clients, delay_model,
                                     straggler_scale, participation)
    if lazy:
        if deadline > 0:
            raise ValueError("lazy schedules cannot apply a deadline: the "
                             "deadline mask needs every client's delay — "
                             "use the dense stream for deadline runs")
        yield SparseSchedule(seed=seed, n_rounds=n_rounds,
                             population=population, t_server=t_server,
                             t_gen=t_gen, t_comm=t_comm)
        return
    M = population.n_clients
    rng = np.random.default_rng(seed)
    sampler = population.sampler()
    t_comm_scale = (None if population.uniform_comm
                    else population.t_comm_scales())
    done = 0
    while done < n_rounds:
        C = min(int(chunk_rounds), n_rounds - done)
        delays = np.empty((C, M), np.float64)
        parts = np.empty((C, M), np.float32)
        for r in range(C):
            delays[r] = sampler.delays_row(rng)
            parts[r] = sampler.participation_row(rng)
        dead = np.stack([deadline_mask(delays[r], deadline)
                         for r in range(C)])
        yield Schedule(delays=delays, participation=parts, deadline=dead,
                       masks=parts * dead,
                       fresh_median=median_fresh_mask(delays),
                       seed=seed, t_server=t_server, t_gen=t_gen,
                       t_comm=t_comm, t_comm_scale=t_comm_scale,
                       population=population)
        done += C


# ---------------------------------------------------------------------------
# lazy fleet schedules: the streaming mask protocol (never densified)
# ---------------------------------------------------------------------------

def _mix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, vectorized over uint64 (wrapping arithmetic).
    Counter-based keyed randomness for the lazy schedule's per-client
    draws: hashing (seed, round, client-id) costs O(ids) with a numpy-op
    constant, where a per-client Generator init would cost ~30us each —
    the difference between O(K) and O(K · rng-setup) per DES version."""
    x = x.astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _hash_uniform(seed: int, lane: int, r: int, ids: np.ndarray) -> np.ndarray:
    """Deterministic U(0, 1) per (seed, lane, round, id), open interval."""
    key = _mix64(_mix64(np.array([seed], np.uint64) ^
                        (np.uint64(lane) << np.uint64(32))) ^ np.uint64(r))
    h = _mix64(key ^ ids.astype(np.uint64))
    return ((h >> np.uint64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


def _sample_ids(rng: np.random.Generator, n: int, k: int) -> np.ndarray:
    """k distinct ints from [0, n), sorted — O(k) when k << n (rejection
    sampling), falling back to numpy's permutation draw for dense k."""
    if k >= n:
        return np.arange(n, dtype=np.int64)
    if k > n // 2 or n < 64:
        return np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)
    seen: set = set()
    while len(seen) < k:
        for x in rng.integers(0, n, size=2 * (k - len(seen))):
            if len(seen) >= k:
                break
            seen.add(int(x))
    return np.sort(np.fromiter(seen, np.int64, len(seen)))


def _sample_from_complement(rng: np.random.Generator, n: int,
                            exclude: np.ndarray, k: int) -> np.ndarray:
    """k distinct ints from [0, n) \\ exclude (sorted ascending), sorted."""
    n_avail = n - exclude.size
    if k <= 0:
        return np.empty(0, np.int64)
    if k >= n_avail or exclude.size > n // 2 or n < 64:
        avail = np.setdiff1d(np.arange(n, dtype=np.int64), exclude,
                             assume_unique=True)
        if k >= avail.size:
            return avail
        return avail[np.sort(rng.choice(avail.size, size=k, replace=False))]
    excl = set(exclude.tolist())
    seen: set = set()
    while len(seen) < k:
        for x in rng.integers(0, n, size=2 * (k - len(seen))):
            if len(seen) >= k:
                break
            xi = int(x)
            if xi not in excl:
                seen.add(xi)
    return np.sort(np.fromiter(seen, np.int64, len(seen)))


def _markov_down_rows(rng: np.random.Generator, n: int, p_drop: float,
                      p_rec: float, n_rounds: int) -> list:
    """Per-round sorted down-sets of an n-client up/down chain, sampled by
    flip COUNTS (binomial) + uniform subset draws — distributionally
    identical to n independent per-client flips, at O(flips + |down|) per
    round instead of O(n). Starts all-up with one transition before round
    0, matching PopulationSampler."""
    down = np.empty(0, np.int64)
    rows = []
    for _ in range(n_rounds):
        n_up = n - down.size
        k_dn = int(rng.binomial(n_up, p_drop)) if n_up and p_drop > 0 else 0
        k_rc = (int(rng.binomial(down.size, p_rec))
                if down.size and p_rec > 0 else 0)
        new_down = _sample_from_complement(rng, n, down, k_dn)
        if k_rc:
            rec = np.sort(rng.choice(down.size, size=k_rc, replace=False))
            down = np.delete(down, rec)
        if new_down.size:
            down = np.sort(np.concatenate([down, new_down]))
        rows.append(down.copy())
    return rows


@dataclasses.dataclass
class SparseSchedule:
    """A lazily-sampled fleet schedule — the streaming mask protocol.

    Never materializes (R, M) rows: availability comes back as per-cohort
    AvailRows (``avail_row``) and delays are evaluated only for the
    clients a DES version actually admits (``delays_for``), each draw
    keyed on (seed, stream, round, cohort/client-id). Deterministic and
    random-access in the round index, so the sparse TimelineStream can
    consume it in place of a dense Schedule and million-client fleets
    cost O(K + availability events) per version, not O(M).

    NOT RNG-compatible with make_schedule: the dense sampler consumes one
    sequential stream (and its participation draw is O(M) even at
    fraction 1.0), so the same seed yields a different — equally valid —
    draw. Fleets whose rows are deterministic (scale-0 delays, full
    participation, no chains) are identical by construction; tests gate
    that, plus distributional agreement for the stochastic parts. Markov
    chains are precomputed per cohort at O(flips) per round (memory
    scales with outage density, not fleet size); ``deadline`` is
    unsupported here — it needs the full delay row by definition.
    """
    seed: int
    n_rounds: int
    population: ClientPopulation
    t_server: float = 0.1
    t_gen: float = 0.0
    t_comm: float = 0.0

    def __post_init__(self):
        if self.n_rounds < 1:
            raise ValueError("SparseSchedule needs n_rounds >= 1")
        self._slices = self.population.slices()
        self._bounds = [(s.start, s.stop) for s in self._slices]
        self._his = np.array([hi for _, hi in self._bounds], np.int64)
        # availability chains, precomputed per cohort (O(R) scalars for
        # shared chains; O(R · outage size) for per-client chains)
        self._shared_up: Dict[int, np.ndarray] = {}
        self._down_rows: Dict[int, list] = {}
        for i, c in enumerate(self.population.cohorts):
            if c.availability == "markov-shared":
                rng = np.random.default_rng((self.seed, 2, i))
                up, ups = True, np.empty(self.n_rounds, bool)
                for r in range(self.n_rounds):
                    u = rng.random()
                    up = (u >= c.p_dropout) if up else (u < c.p_recover)
                    ups[r] = up
                self._shared_up[i] = ups
            elif c.availability == "markov":
                rng = np.random.default_rng((self.seed, 3, i))
                self._down_rows[i] = _markov_down_rows(
                    rng, c.n, c.p_dropout, c.p_recover, self.n_rounds)

    @property
    def n_clients(self) -> int:
        return self.population.n_clients

    @property
    def t_comm_scale(self) -> Optional[np.ndarray]:
        return (None if self.population.uniform_comm
                else self.population.t_comm_scales())

    def _part_ids(self, r: int, i: int, c: Cohort) -> np.ndarray:
        """Cohort-local sorted participation draw (always >= 1 active —
        the participation_mask convention)."""
        k = max(1, int(round(c.participation * c.n)))
        rng = np.random.default_rng((self.seed, 0, r, i))
        return _sample_ids(rng, c.n, k)

    def avail_row(self, r: int) -> AvailRow:
        """This round's availability as per-cohort sparse records."""
        kinds, ids = [], []
        for i, (c, (lo, _hi)) in enumerate(
                zip(self.population.cohorts, self._bounds)):
            if c.availability == "markov-shared" and not self._shared_up[i][r]:
                kinds.append("none")
                ids.append(None)
                continue
            down = (self._down_rows[i][r] if c.availability == "markov"
                    else np.empty(0, np.int64))
            if c.participation >= 1.0:
                if down.size == 0:
                    kinds.append("all")
                    ids.append(None)
                elif down.size == c.n:
                    kinds.append("none")
                    ids.append(None)
                else:
                    kinds.append("not_ids")
                    ids.append(down + lo)
                continue
            part = self._part_ids(r, i, c)
            if down.size:
                pos = np.minimum(np.searchsorted(down, part), down.size - 1)
                part = part[down[pos] != part]
            if part.size:
                kinds.append("ids")
                ids.append(part + lo)
            else:
                kinds.append("none")
                ids.append(None)
        return AvailRow(list(self._bounds), kinds, ids)

    def delays_for(self, r: int, ids: np.ndarray) -> np.ndarray:
        """Delays for exactly ``ids`` (global, ascending), keyed
        (seed, round, id) via the counter-based hash — O(ids), vectorized,
        no per-client Generator setup. t = base·(1 + Exp(scale)) with
        Exp(scale) = -scale·ln(U), the DelayModel distribution."""
        ids = np.asarray(ids, np.int64)
        out = np.empty(ids.size, np.float64)
        coh = np.searchsorted(self._his, ids, side="right")
        u = None
        for i in np.unique(coh).tolist():
            sel = coh == i
            d = self.population.cohorts[i].delay
            if d.scale > 0:
                if u is None:
                    u = _hash_uniform(self.seed, 1, r, ids)
                out[sel] = d.base * (1.0 - d.scale * np.log(u[sel]))
            else:
                out[sel] = d.base
            if d.hetero is not None:
                h = np.asarray(d.hetero)
                out[sel] = out[sel] * h[ids[sel] - self._bounds[i][0]]
        return out


def make_sparse_schedule(seed: int, n_rounds: int,
                         n_clients: Optional[int] = None, *,
                         population: Optional[ClientPopulation] = None,
                         delay_model: Optional[DelayModel] = None,
                         straggler_scale: float = 0.0,
                         participation: float = 1.0,
                         t_server: float = 0.1, t_gen: float = 0.0,
                         t_comm: float = 0.0) -> SparseSchedule:
    """The lazy counterpart of make_schedule — same fleet/knob surface,
    but rows are sampled on demand through the streaming mask protocol
    (SparseSchedule) instead of materialized as (R, M) arrays."""
    population = _resolve_population(population, n_clients, delay_model,
                                     straggler_scale, participation)
    return SparseSchedule(seed=seed, n_rounds=n_rounds,
                          population=population, t_server=t_server,
                          t_gen=t_gen, t_comm=t_comm)


# ---------------------------------------------------------------------------
# wall-clock round-time models (per algorithm)
# ---------------------------------------------------------------------------

def round_time_mu_splitfed(client_times: np.ndarray, mask: np.ndarray,
                           t_server: float, tau: int,
                           t_comm: float = 0.0) -> float:
    """Server overlaps its τ local steps with client compute: the round ends
    when BOTH the slowest active client and the server's τ steps are done."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return max(t_straggler, tau * t_server) + t_comm


def round_time_vanilla(client_times: np.ndarray, mask: np.ndarray,
                       t_server: float, t_comm: float = 0.0) -> float:
    """Vanilla SplitFed: strictly serialized client -> server dependency."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return t_straggler + t_server + t_comm


def round_time_gas(client_times: np.ndarray, mask: np.ndarray,
                   t_server: float, t_gen: float,
                   t_comm: float = 0.0) -> float:
    """GAS-like async: proceeds at the median client's pace but pays an
    activation-generation overhead t_gen each round (paper §5 discussion)."""
    active = client_times[mask > 0]
    t_med = float(np.median(active)) if active.size else 0.0
    return t_med + t_server + t_gen + t_comm


def round_time_local_only(client_times: np.ndarray, mask: np.ndarray,
                          t_comm: float = 0.0) -> float:
    """FedAvg/FedLoRA: no split-server compute; the round is bounded by the
    slowest active client's full local pass plus the model exchange."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return t_straggler + t_comm


class WallClock:
    """Accumulates simulated time across rounds (one per algorithm run)."""

    def __init__(self) -> None:
        self.t = 0.0
        self.per_round = []

    def tick(self, dt: float) -> float:
        self.t += dt
        self.per_round.append(dt)
        return self.t


def simulate_total_time(algorithm: str, delays: np.ndarray,
                        masks: np.ndarray, t_server: float, tau: int,
                        t_gen: float = 0.0, t_comm: float = 0.0,
                        rounds_needed: Optional[int] = None) -> float:
    """Total wall-clock for ``rounds_needed`` rounds (default: all rows).

    For MU-SplitFed the τ-speedup also divides the number of rounds needed
    to converge (Cor. 4.4: T₁ = T₀/τ) — the caller passes the appropriate
    rounds_needed per algorithm; this function only sums round times.
    """
    n = rounds_needed if rounds_needed is not None else delays.shape[0]
    total = 0.0
    for r in range(n):
        row, m = delays[r % delays.shape[0]], masks[r % masks.shape[0]]
        if algorithm == "mu_splitfed":
            total += round_time_mu_splitfed(row, m, t_server, tau, t_comm)
        elif algorithm == "vanilla":
            total += round_time_vanilla(row, m, t_server, t_comm)
        elif algorithm == "gas":
            total += round_time_gas(row, m, t_server, t_gen, t_comm)
        else:
            raise ValueError(algorithm)
    return total
