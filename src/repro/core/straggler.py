"""Straggler system model: device heterogeneity, wall-clock simulation,
deadline-based participation, and the paper's τ-planner.

The paper (§5) simulates heterogeneity by sampling per-client computation
time from an exponential distribution; Eq. 12 shows that with
τ = t_straggler / t_server the total time T₀·t_straggler/τ = T₀·t_server
becomes independent of the straggler. This module reproduces that system
model and exposes it to the trainer as *data* (delays, masks) — the jit'd
round math never blocks on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "DelayModel", "Schedule", "make_schedule", "participation_mask",
    "deadline_mask", "median_fresh_mask", "plan_tau",
    "round_time_mu_splitfed", "round_time_vanilla", "round_time_gas",
    "round_time_local_only", "WallClock", "simulate_total_time",
]


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Per-round client compute times (seconds, simulated).

    t_m = base * (1 + Exp(scale))  — heterogeneous, heavy-tailed (paper §5
    follows [8,12] and samples from an exponential distribution).
    ``hetero`` optionally fixes a per-client speed multiplier (systematic
    stragglers rather than purely stochastic ones).
    """
    base: float = 1.0
    scale: float = 1.0
    hetero: Optional[Tuple[float, ...]] = None

    def sample(self, rng: np.random.Generator, n_clients: int,
               n_rounds: int) -> np.ndarray:
        t = self.base * (1.0 + rng.exponential(self.scale,
                                               size=(n_rounds, n_clients)))
        if self.hetero is not None:
            t = t * np.asarray(self.hetero)[None, :]
        return t


def participation_mask(rng: np.random.Generator, n_clients: int,
                       fraction: float) -> np.ndarray:
    """Random partial participation (paper: 50%). Always >=1 active."""
    k = max(1, int(round(fraction * n_clients)))
    idx = rng.choice(n_clients, size=k, replace=False)
    m = np.zeros((n_clients,), np.float32)
    m[idx] = 1.0
    return m


def deadline_mask(delays: np.ndarray, deadline: float) -> np.ndarray:
    """Drop clients slower than the deadline (straggler mitigation knob)."""
    if deadline <= 0:
        return np.ones_like(delays, np.float32)
    m = (delays <= deadline).astype(np.float32)
    if m.sum() == 0:                       # never drop everyone
        m[np.argmin(delays)] = 1.0
    return m


def median_fresh_mask(delays: np.ndarray) -> np.ndarray:
    """GAS freshness rule (Fig. 2 protocol): clients at or below the
    per-round median delay deliver in time; the rest are served from the
    stale activation buffer. delays: (M,) or (R, M); returns same shape."""
    d = np.asarray(delays, np.float64)
    med = np.median(d, axis=-1, keepdims=True)
    return (d <= med).astype(np.float32)


def plan_tau(t_straggler: float, t_server: float, tau_max: int = 64) -> int:
    """Paper Eq. 12: τ* = t_straggler / t_server (clipped, >=1)."""
    return int(np.clip(round(t_straggler / max(t_server, 1e-9)), 1, tau_max))


# ---------------------------------------------------------------------------
# precomputed schedules: the system model as (R, M) data
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """The full system-model trace for an R-round run, precomputed on host.

    The engine (core/engine.py) scans these rows as *data* — the jit'd
    round math never blocks on the host simulator. All arrays are (R, M):

      delays         per-round client compute times (seconds, simulated)
      participation  0/1 random-participation draw
      deadline       0/1 deadline survivors (all-ones when deadline <= 0)
      masks          participation * deadline — what the round consumes
      fresh_median   GAS freshness rule (<= per-round median delay)

    t_server / t_gen / t_comm are the scalar wall-clock model knobs; the
    per-algorithm round-time models read them through this object.
    """
    delays: np.ndarray
    participation: np.ndarray
    deadline: np.ndarray
    masks: np.ndarray
    fresh_median: np.ndarray
    seed: int = 0
    t_server: float = 0.1
    t_gen: float = 0.0
    t_comm: float = 0.0

    @property
    def n_rounds(self) -> int:
        return self.delays.shape[0]

    @property
    def n_clients(self) -> int:
        return self.delays.shape[1]

    def row(self, r: int) -> Tuple[np.ndarray, np.ndarray]:
        """(delays, mask) for absolute round r (cyclic past n_rounds)."""
        i = r % self.n_rounds
        return self.delays[i], self.masks[i]


def make_schedule(seed: int, n_rounds: int, n_clients: int, *,
                  delay_model: Optional[DelayModel] = None,
                  straggler_scale: float = 0.0,
                  participation: float = 1.0,
                  deadline: float = 0.0,
                  t_server: float = 0.1,
                  t_gen: float = 0.0,
                  t_comm: float = 0.0) -> Schedule:
    """Precompute the whole system-model trace as stacked (R, M) arrays.

    Deterministic in (seed, n_rounds, n_clients, knobs). The per-round RNG
    draw order is exactly the historical per-round scalar path of the
    training driver — delays first (only when the delay model is
    heterogeneous), then the participation draw — so a schedule row r
    reproduces what round r of the old Python loop would have sampled
    (tests/test_engine.py pins this).
    """
    dm = delay_model or DelayModel(base=1.0, scale=straggler_scale)
    rng = np.random.default_rng(seed)
    stochastic = dm.scale > 0 or dm.hetero is not None
    delays = np.empty((n_rounds, n_clients), np.float64)
    parts = np.empty((n_rounds, n_clients), np.float32)
    for r in range(n_rounds):
        delays[r] = (dm.sample(rng, n_clients, 1)[0] if stochastic
                     else np.full((n_clients,), dm.base))
        parts[r] = participation_mask(rng, n_clients, participation)
    dead = np.stack([deadline_mask(delays[r], deadline)
                     for r in range(n_rounds)])
    return Schedule(delays=delays, participation=parts, deadline=dead,
                    masks=parts * dead, fresh_median=median_fresh_mask(delays),
                    seed=seed, t_server=t_server, t_gen=t_gen, t_comm=t_comm)


# ---------------------------------------------------------------------------
# wall-clock round-time models (per algorithm)
# ---------------------------------------------------------------------------

def round_time_mu_splitfed(client_times: np.ndarray, mask: np.ndarray,
                           t_server: float, tau: int,
                           t_comm: float = 0.0) -> float:
    """Server overlaps its τ local steps with client compute: the round ends
    when BOTH the slowest active client and the server's τ steps are done."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return max(t_straggler, tau * t_server) + t_comm


def round_time_vanilla(client_times: np.ndarray, mask: np.ndarray,
                       t_server: float, t_comm: float = 0.0) -> float:
    """Vanilla SplitFed: strictly serialized client -> server dependency."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return t_straggler + t_server + t_comm


def round_time_gas(client_times: np.ndarray, mask: np.ndarray,
                   t_server: float, t_gen: float,
                   t_comm: float = 0.0) -> float:
    """GAS-like async: proceeds at the median client's pace but pays an
    activation-generation overhead t_gen each round (paper §5 discussion)."""
    active = client_times[mask > 0]
    t_med = float(np.median(active)) if active.size else 0.0
    return t_med + t_server + t_gen + t_comm


def round_time_local_only(client_times: np.ndarray, mask: np.ndarray,
                          t_comm: float = 0.0) -> float:
    """FedAvg/FedLoRA: no split-server compute; the round is bounded by the
    slowest active client's full local pass plus the model exchange."""
    active = client_times[mask > 0]
    t_straggler = float(active.max()) if active.size else 0.0
    return t_straggler + t_comm


class WallClock:
    """Accumulates simulated time across rounds (one per algorithm run)."""

    def __init__(self) -> None:
        self.t = 0.0
        self.per_round = []

    def tick(self, dt: float) -> float:
        self.t += dt
        self.per_round.append(dt)
        return self.t


def simulate_total_time(algorithm: str, delays: np.ndarray,
                        masks: np.ndarray, t_server: float, tau: int,
                        t_gen: float = 0.0, t_comm: float = 0.0,
                        rounds_needed: Optional[int] = None) -> float:
    """Total wall-clock for ``rounds_needed`` rounds (default: all rows).

    For MU-SplitFed the τ-speedup also divides the number of rounds needed
    to converge (Cor. 4.4: T₁ = T₀/τ) — the caller passes the appropriate
    rounds_needed per algorithm; this function only sums round times.
    """
    n = rounds_needed if rounds_needed is not None else delays.shape[0]
    total = 0.0
    for r in range(n):
        row, m = delays[r % delays.shape[0]], masks[r % masks.shape[0]]
        if algorithm == "mu_splitfed":
            total += round_time_mu_splitfed(row, m, t_server, tau, t_comm)
        elif algorithm == "vanilla":
            total += round_time_vanilla(row, m, t_server, t_comm)
        elif algorithm == "gas":
            total += round_time_gas(row, m, t_server, t_gen, t_comm)
        else:
            raise ValueError(algorithm)
    return total
