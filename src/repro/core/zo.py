"""Zeroth-order (SPSA) engine with seed-replay.

The paper's estimator (Eq. 3): g(x) = (f(x+λu) − f(x−λu)) / (2λ) · u, with u
either Gaussian (MeZO-style) or uniform on the sphere √d·S^{d-1} (the
paper's choice). Perturbations are *never materialized as state*: each is a
pure function of a PRNG key, so

  * perturb-forward-perturb needs no extra parameter-sized buffer beyond the
    functional temporary (MeZO's trick, expressed functionally);
  * an entire ZO update is the scalar pair ``(key, coeff)`` — replaying it
    regenerates u on the fly. This is the "dimension-free communication" of
    paper Appendix A, and our compressed-aggregation wire format.

All helpers are pytree-generic: they work on client halves, server halves,
or full models.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class UpdateRecord(NamedTuple):
    """One replayable ZO update: x <- x - coeff * u(key).  O(1) bytes."""
    key: jax.Array     # PRNG key
    coeff: jax.Array   # scalar f32 (already includes lr * delta / (2 lambda))


# ---------------------------------------------------------------------------
# noise
# ---------------------------------------------------------------------------

def _leaf_keys(key, params: Params):
    """One fold_in-derived key per leaf — deterministic in tree structure,
    independent of sharding/mesh (jax.random is shape-deterministic)."""
    leaves, treedef = jax.tree.flatten(params)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, keys)


def tree_noise(key, params: Params, dist: str = "gaussian") -> Params:
    """u with the same structure/shapes as params (f32 leaves).

    dist='gaussian': iid N(0,1) via threefry (jax.random).
    dist='counter' : iid N(0,1) via the counter-based murmur3+Box-Muller
        generator of kernels/ref.py — ~3 HLO ops/element instead of
        threefry's long chain; the fused Pallas zo_update kernel applies
        the same hash family on-chip on TPU (beyond-paper optimization;
        still an exact SPSA gaussian).
    dist='sphere'  : gaussian scaled to ‖u‖=√d globally (the paper's
        √d·S^{d-1}); needs a global norm, hence two passes.
    """
    if dist == "counter":
        # Sharding-friendly: the (row, col) counters are built from
        # leaf-SHAPED iotas (row = flattened leading dims, col = last dim),
        # so the whole generator is elementwise in the leaf's layout and
        # GSPMD partitions it exactly like the parameter it perturbs — no
        # reshapes, no gathers (the v2 lesson in EXPERIMENTS.md §Perf).
        from repro.kernels.ref import counter_gauss2
        leaves, treedef = jax.tree.flatten(params)
        base = (jnp.asarray(key).reshape(-1)[0]
                ^ jnp.asarray(key).reshape(-1)[-1]).astype(jnp.uint32)
        out = []
        for i, leaf in enumerate(leaves):
            seed = base ^ jnp.uint32((i * 0x9E3779B9) & 0xFFFFFFFF)
            shape = leaf.shape if leaf.ndim > 0 else (1,)
            # row = linear index over all-but-last dims; col = last dim
            row = jnp.zeros(shape, jnp.uint32)
            mult = 1
            for d in range(len(shape) - 2, -1, -1):
                row = row + jax.lax.broadcasted_iota(jnp.uint32, shape, d) \
                    * jnp.uint32(mult)
                mult *= shape[d]
            col = jax.lax.broadcasted_iota(jnp.uint32, shape,
                                           len(shape) - 1)
            u = counter_gauss2(seed, row, col)
            out.append(u.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, out)
    ks = _leaf_keys(key, params)
    u = jax.tree.map(lambda p, k: jax.random.normal(k, p.shape, jnp.float32),
                     params, ks)
    if dist == "sphere":
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(u))
        d = sum(x.size for x in jax.tree.leaves(u))
        u = jax.tree.map(lambda x: x * (jnp.sqrt(float(d)) / jnp.sqrt(sq)), u)
    return u


def perturb(params: Params, key, scale, dist: str = "gaussian") -> Params:
    """x + scale * u(key). ``scale`` may be a traced scalar (e.g. ±λ)."""
    u = tree_noise(key, params, dist)
    return jax.tree.map(lambda p, n: (p + scale * n).astype(p.dtype), params, u)


def apply_update(params: Params, key, coeff, dist: str = "gaussian") -> Params:
    """x - coeff * u(key): replay one UpdateRecord."""
    return perturb(params, key, -coeff, dist)


def replay_updates(params: Params, keys, coeffs, dist: str = "gaussian") -> Params:
    """Apply a batch of records sequentially (order-independent: updates are
    additive once the coeffs are fixed). keys: (N,) key array; coeffs: (N,)."""
    def body(p, rec):
        k, c = rec
        return apply_update(p, k, c, dist), None
    out, _ = jax.lax.scan(body, params, (keys, coeffs))
    return out


# ---------------------------------------------------------------------------
# SPSA estimation
# ---------------------------------------------------------------------------

def spsa_delta(loss_of: Callable[[Params], jax.Array], params: Params, key,
               eps: float, dist: str = "gaussian") -> jax.Array:
    """δ = f(x+λu) − f(x−λu) for one perturbation. Two forwards."""
    lp = loss_of(perturb(params, key, +eps, dist))
    lm = loss_of(perturb(params, key, -eps, dist))
    return (lp - lm).astype(jnp.float32)


def spsa_step(loss_of: Callable[[Params], jax.Array], params: Params, key,
              eps: float, lr, n_perturbations: int = 1,
              dist: str = "gaussian") -> Tuple[Params, jax.Array, Tuple]:
    """One ZO-SGD step with P-perturbation averaging.

    Returns (new_params, mean_delta, records) where records = (keys, coeffs)
    are the replayable wire format (P entries).
    """
    P = n_perturbations
    pkeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(P))

    def one(i, carry):
        deltas = carry
        d = spsa_delta(loss_of, params, pkeys[i], eps, dist)
        return deltas.at[i].set(d)

    deltas = jax.lax.fori_loop(0, P, one, jnp.zeros((P,), jnp.float32))
    coeffs = lr * deltas / (2.0 * eps * P)
    new_params = replay_updates(params, pkeys, coeffs, dist)
    return new_params, jnp.mean(deltas), (pkeys, coeffs)


def zo_gradient(loss_of: Callable[[Params], jax.Array], params: Params, key,
                eps: float, n_perturbations: int = 1,
                dist: str = "gaussian") -> Params:
    """Materialized ZO gradient estimate (tests / analysis only — training
    paths use spsa_step's replay form and never build this tree)."""
    P = n_perturbations
    g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(P):
        k = jax.random.fold_in(key, i)
        d = spsa_delta(loss_of, params, k, eps, dist)
        u = tree_noise(k, params, dist)
        g = jax.tree.map(lambda a, n: a + (d / (2 * eps * P)) * n, g, u)
    return g
