"""Zeroth-order (SPSA) engine with seed-replay.

The paper's estimator (Eq. 3): g(x) = (f(x+λu) − f(x−λu)) / (2λ) · u, with u
either Gaussian (MeZO-style) or uniform on the sphere √d·S^{d-1} (the
paper's choice). Perturbations are *never materialized as state*: each is a
pure function of a PRNG key, so

  * perturb-forward-perturb needs no extra parameter-sized buffer beyond the
    functional temporary (MeZO's trick, expressed functionally);
  * an entire ZO update is the scalar pair ``(key, coeff)`` — replaying it
    regenerates u on the fly. This is the "dimension-free communication" of
    paper Appendix A, and our compressed-aggregation wire format.

Replaying a BATCH of N records has two implementations:

  * ``replay_updates``        sequential lax.scan — N full parameter-sized
                              HBM read+write sweeps (ladder v3; the only
                              option for threefry gaussian/sphere noise);
  * ``fused_replay_updates``  one-pass batched replay for dist='counter'
                              (ladder v4): per leaf, all N counter-gaussian
                              contributions are regenerated and accumulated
                              locally (in VMEM by the Pallas zo_replay
                              kernel on TPU, via kernels/ref.py elsewhere)
                              before x is touched — one HBM read + one
                              write per leaf regardless of N. This is what
                              makes seed-replay aggregation O(1) parameter
                              sweeps instead of O(Mτ P).

The counter noise stream is layout-unified with the kernels: element with
row-major linear index n in leaf i draws from
``counter_gauss2(base ^ i·φ, n // 1024, n % 1024)`` — identical for
tree_noise, the Pallas kernels, and the ref oracles, so a record written
by the engine replays through the kernels on bit-identical noise (summed
results agree up to f32 accumulation order).

All helpers are pytree-generic: they work on client halves, server halves,
or full models.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class UpdateRecord(NamedTuple):
    """One replayable ZO update: x <- x - coeff * u(key).  O(1) bytes."""
    key: jax.Array     # PRNG key
    coeff: jax.Array   # scalar f32 (already includes lr * delta / (2 lambda))


# ---------------------------------------------------------------------------
# noise
# ---------------------------------------------------------------------------

def _leaf_keys(key, params: Params):
    """One fold_in-derived key per leaf — deterministic in tree structure,
    independent of sharding/mesh (jax.random is shape-deterministic)."""
    leaves, treedef = jax.tree.flatten(params)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, keys)


_LEAF_SALT = 0x9E3779B9          # golden-ratio leaf decorrelation constant


def record_seeds(keys) -> jax.Array:
    """uint32 counter seed(s) from PRNG key(s): first ^ last key word.
    Accepts one key (shape (2,)) or a batch ((N, 2) / any leading dims);
    the scalar form is the per-record ``base`` of tree_noise('counter')."""
    raw = jnp.asarray(keys, jnp.uint32)
    return (raw[..., 0] ^ raw[..., -1]).astype(jnp.uint32)


def _leaf_seed(base, leaf_idx: int):
    """Per-leaf counter seed — shared by tree_noise('counter') and
    fused_replay_updates so both draw the identical stream."""
    return base ^ jnp.uint32((leaf_idx * _LEAF_SALT) & 0xFFFFFFFF)


def tree_noise(key, params: Params, dist: str = "gaussian") -> Params:
    """u with the same structure/shapes as params (f32 leaves).

    dist='gaussian': iid N(0,1) via threefry (jax.random).
    dist='counter' : iid N(0,1) via the counter-based murmur3+Box-Muller
        generator of kernels/ref.py — ~3 HLO ops/element instead of
        threefry's long chain; the fused Pallas zo_update kernel applies
        the same hash family on-chip on TPU (beyond-paper optimization;
        still an exact SPSA gaussian).
    dist='sphere'  : gaussian scaled to ‖u‖=√d globally (the paper's
        √d·S^{d-1}); needs a global norm, hence two passes.
    """
    if dist == "counter":
        # Sharding-friendly: the (hi, lo) counters are built from
        # leaf-SHAPED iotas (hi/lo = row-major linear index split at the
        # kernel LANE), so the whole generator is elementwise in the leaf's
        # layout and GSPMD partitions it exactly like the parameter it
        # perturbs — no reshapes, no gathers (the v2 lesson in
        # EXPERIMENTS.md §Perf). The split at LANE=1024 makes the stream
        # identical to the (row, lane) layout of the Pallas zo_update /
        # zo_replay kernels and the kernels/ref.py oracles, which is what
        # lets fused_replay_updates replay engine-generated records.
        from repro.kernels.ref import LANE, counter_gauss2
        leaves, treedef = jax.tree.flatten(params)
        base = record_seeds(jnp.asarray(key).reshape(-1))
        out = []
        for i, leaf in enumerate(leaves):
            seed = _leaf_seed(base, i)
            shape = leaf.shape if leaf.ndim > 0 else (1,)
            # row-major linear element index, built elementwise
            lin = jnp.zeros(shape, jnp.uint32)
            mult = 1
            for d in range(len(shape) - 1, -1, -1):
                lin = lin + jax.lax.broadcasted_iota(jnp.uint32, shape, d) \
                    * jnp.uint32(mult)
                mult *= shape[d]
            u = counter_gauss2(seed, lin // jnp.uint32(LANE),
                               lin % jnp.uint32(LANE))
            out.append(u.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, out)
    ks = _leaf_keys(key, params)
    u = jax.tree.map(lambda p, k: jax.random.normal(k, p.shape, jnp.float32),
                     params, ks)
    if dist == "sphere":
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(u))
        d = sum(x.size for x in jax.tree.leaves(u))
        u = jax.tree.map(lambda x: x * (jnp.sqrt(float(d)) / jnp.sqrt(sq)), u)
    return u


def perturb(params: Params, key, scale, dist: str = "gaussian") -> Params:
    """x + scale * u(key). ``scale`` may be a traced scalar (e.g. ±λ)."""
    u = tree_noise(key, params, dist)
    return jax.tree.map(lambda p, n: (p + scale * n).astype(p.dtype), params, u)


def apply_update(params: Params, key, coeff, dist: str = "gaussian") -> Params:
    """x - coeff * u(key): replay one UpdateRecord."""
    return perturb(params, key, -coeff, dist)


def replay_updates(params: Params, keys, coeffs, dist: str = "gaussian") -> Params:
    """Apply a batch of records sequentially (order-independent: updates are
    additive once the coeffs are fixed). keys: (N,) key array; coeffs: (N,).

    Each scan step regenerates a full parameter-sized noise tree and does a
    full HBM read+write of params — N sweeps total. Prefer
    ``fused_replay_updates`` (one sweep) whenever dist='counter'."""
    def body(p, rec):
        k, c = rec
        return apply_update(p, k, c, dist), None
    out, _ = jax.lax.scan(body, params, (keys, coeffs))
    return out


def fused_replay_updates(params: Params, keys, coeffs,
                         dist: str = "gaussian",
                         impl: str = "auto") -> Params:
    """One-pass batched replay of N UpdateRecords: x − Σᵢ cᵢ·u(keyᵢ).

    The seed-replay aggregation hot path (perf-ladder v4). For
    dist='counter', each leaf's N counter-gaussian contributions are
    regenerated and accumulated locally — in VMEM by the Pallas
    ``zo_replay_flat`` kernel on TPU, by the ``kernels/ref.py`` oracle
    elsewhere — before the leaf is touched: one HBM read + one write per
    leaf regardless of N, versus the N full parameter sweeps of the
    ``replay_updates`` scan. Equivalent to that scan up to f32 summation
    order (≤1e-5; see tests/test_replay.py).

    dist='gaussian'/'sphere' (threefry noise, not counter-replayable) fall
    back to the sequential scan. impl: 'auto' | 'fused' | 'scan' — 'scan'
    forces the sequential path (the v3 rung / equivalence baseline);
    'fused' asserts the one-pass path (counter only).
    """
    if impl == "scan" or (impl == "auto" and dist != "counter"):
        return replay_updates(params, keys, coeffs, dist)
    if dist != "counter":
        raise ValueError(
            f"fused replay requires dist='counter', got {dist!r}")
    from repro.kernels.ops import zo_replay_leaf
    seeds = record_seeds(keys)                       # (N,) uint32
    neg_coeffs = -jnp.asarray(coeffs, jnp.float32).reshape(-1)
    leaves, treedef = jax.tree.flatten(params)
    out = [zo_replay_leaf(leaf, _leaf_seed(seeds, i), neg_coeffs)
           for i, leaf in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def replay_weighted_records(params: Params, keys, coeffs, weights,
                            dist: str = "gaussian",
                            impl: str = "auto") -> Params:
    """Replay per-client record stacks with aggregation weights — the
    shared wire-format apply of every seed-replay aggregation site.

    keys: (M, ..., 2) stacked record keys; coeffs: (M, ...) matching
    scalars; weights: (M,) aggregation weights (e.g. η_g·w_m). Flattens to
    N = M·(...) records with coeff cᵢ·w_m and applies them through
    fused_replay_updates."""
    coeffs = jnp.asarray(coeffs, jnp.float32)
    w = jnp.asarray(weights, jnp.float32).reshape(
        (-1,) + (1,) * (coeffs.ndim - 1))
    flat_keys = keys.reshape((-1,) + keys.shape[-1:])
    return fused_replay_updates(params, flat_keys, (coeffs * w).reshape(-1),
                                dist, impl=impl)


# ---------------------------------------------------------------------------
# SPSA estimation
# ---------------------------------------------------------------------------

def spsa_delta(loss_of: Callable[[Params], jax.Array], params: Params, key,
               eps: float, dist: str = "gaussian") -> jax.Array:
    """δ = f(x+λu) − f(x−λu) for one perturbation. Two forwards."""
    lp = loss_of(perturb(params, key, +eps, dist))
    lm = loss_of(perturb(params, key, -eps, dist))
    return (lp - lm).astype(jnp.float32)


def spsa_step(loss_of: Callable[[Params], jax.Array], params: Params, key,
              eps: float, lr, n_perturbations: int = 1,
              dist: str = "gaussian",
              replay: str = "auto") -> Tuple[Params, jax.Array, Tuple]:
    """One ZO-SGD step with P-perturbation averaging.

    Returns (new_params, mean_delta, records) where records = (keys, coeffs)
    are the replayable wire format (P entries). ``replay`` selects the
    record-application path (see fused_replay_updates).
    """
    P = n_perturbations
    pkeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(P))

    def one(i, carry):
        deltas = carry
        d = spsa_delta(loss_of, params, pkeys[i], eps, dist)
        return deltas.at[i].set(d)

    deltas = jax.lax.fori_loop(0, P, one, jnp.zeros((P,), jnp.float32))
    coeffs = lr * deltas / (2.0 * eps * P)
    new_params = fused_replay_updates(params, pkeys, coeffs, dist,
                                      impl=replay)
    return new_params, jnp.mean(deltas), (pkeys, coeffs)


def zo_gradient(loss_of: Callable[[Params], jax.Array], params: Params, key,
                eps: float, n_perturbations: int = 1,
                dist: str = "gaussian") -> Params:
    """Materialized ZO gradient estimate (tests / analysis only — training
    paths use spsa_step's replay form and never build this tree)."""
    P = n_perturbations
    g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(P):
        k = jax.random.fold_in(key, i)
        d = spsa_delta(loss_of, params, k, eps, dist)
        u = tree_noise(k, params, dist)
        g = jax.tree.map(lambda a, n: a + (d / (2 * eps * P)) * n, g, u)
    return g
