"""Event-driven semi-async execution: arrival-ordered server updates with
quorum aggregation and staleness-weighted seed replay.

The engine's sync modes run a hard round barrier: every server commit waits
for the full per-round mask, so one slow cohort stalls the fleet — exactly
the synchronization cost the paper identifies. This module is the execution
substrate that drops the barrier while keeping every device-side shape
fixed:

  compile_timeline   a host-side discrete-event simulator over the existing
                     straggler.Schedule. Clients fetch the newest params at
                     each server-version broadcast and deliver their
                     contribution delay + uplink later; the server COMMITS
                     version v+1 as soon as a quorum of K contributions has
                     arrived (FedBuff-style semi-async; K=0 means "all
                     pending" — the synchronous barrier). Contributions
                     that miss the commit are NOT dropped: they fold into a
                     later commit with staleness s = commits missed, and a
                     discount^s weight. The product is a globally
                     arrival-ordered, fixed-shape event stream — stacked
                     (E,) arrays of (arrival_time, client_id, cohort_id,
                     round_of_origin, staleness) — plus its per-version
                     compiled form ((V, M) start/apply matrices and (V,)
                     commit times) that the engine scans as *data*.
  async_round_fn     the jit'd per-version step. Because every MU-SplitFed
                     contribution is replayable seed-records ((key, coeff)
                     pairs — zo.py's wire format), the whole in-flight
                     buffer is a fixed (M, τ, P) record store carried as
                     engine state: committing a quorum is one
                     zo.replay_weighted_records call with the timeline's
                     staleness-discounted weights scaled per record — no
                     new kernel, the fused one-sweep replay path (ladder
                     v4) applies the buffer regardless of which versions
                     its records came from.

Semantics (the "semi" in semi-async): client work is version-aligned —
a client only fetches params and starts a fresh contribution at a version
broadcast (the commit it was applied in, or later), never mid-version; the
server is fully event-driven and commits on quorum arrival. With quorum
K=0/K>=M and discount 1.0 every version's buffer is exactly the sync
round's active set with the sync weights, so mode='async' reproduces
mode='scan' (tests/test_events.py gates <=1e-5).

Wall-clock model: version duration = max(K-th pending arrival, τ·t_server)
— the unbalanced server steps still overlap the wait (Eq. 12) — where an
arrival is fetch_time + delay + t_comm·uplink_scale. Note this charges the
uplink per arrival (the sync models charge the slowest active uplink once
per round), which is the natural accounting once arrivals, not round
maxima, pace the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import zo
from repro.core.splitfed import _client_round
from repro.models import merge_params, split_params

Params = Any

__all__ = ["Timeline", "compile_timeline", "quorum_round_time",
           "init_store", "resize_store", "async_mu_splitfed_step"]


# ---------------------------------------------------------------------------
# the event compiler (host-side discrete-event simulation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Timeline:
    """A compiled semi-async execution trace.

    Flat, globally arrival-ordered event view — one row per delivered
    contribution, all (E,):

      arrival_time     absolute simulated delivery time
      client_id        which client delivered
      cohort_id        its population cohort (0 for scalar fleets)
      round_of_origin  the version whose params/batch/mask it consumed
      staleness        commits between fetch and apply (>=1 means it missed
                       its own version's quorum and folded forward)
      commit_idx       the version commit that applied it (-1: still in
                       flight when the horizon ended — never applied)

    Per-version compiled form the engine scans as data:

      start_mask   (V, M) 1.0 where a client fetches params and begins a
                   fresh contribution at this version's broadcast
      apply_w      (V, M) normalized staleness-discounted aggregation
                   weights of the records this commit applies (rows sum to
                   1, or 0 for an empty commit); 0 = not applied
      staleness_m  (V, M) staleness of the applied record (-1 = not applied)
      commit_times (V,)   absolute commit completion times
      durations    (V,)   per-version wall-clock (commit_times diffs)
      quorum_wait  (V,)   time from broadcast to the quorum arrival, BEFORE
                   the τ·t_server server floor — what an adaptive-τ
                   controller should fill with server steps (Eq. 12)
      applied      (V,)   contributions folded into each commit
    """
    arrival_time: np.ndarray
    client_id: np.ndarray
    cohort_id: np.ndarray
    round_of_origin: np.ndarray
    staleness: np.ndarray
    commit_idx: np.ndarray
    start_mask: np.ndarray
    apply_w: np.ndarray
    staleness_m: np.ndarray
    commit_times: np.ndarray
    durations: np.ndarray
    quorum_wait: np.ndarray
    applied: np.ndarray
    quorum: int
    discount: float
    tau_per_version: np.ndarray

    @property
    def n_versions(self) -> int:
        return self.start_mask.shape[0]

    @property
    def n_clients(self) -> int:
        return self.start_mask.shape[1]

    @property
    def n_events(self) -> int:
        return self.arrival_time.shape[0]


def compile_timeline(schedule, n_versions: int, *, quorum: int = 0,
                     discount: float = 1.0, tau=1,
                     mask_rows: Optional[np.ndarray] = None) -> Timeline:
    """Compile ``n_versions`` semi-async server versions from a Schedule.

    quorum    K: commit as soon as K of the pending contributions have
              arrived (K<=0 or K>=pending: wait for all — the sync
              barrier). A commit folds in *everything* delivered by the
              commit moment, quorum members and opportunistic extras alike.
    discount  staleness weight base: a contribution applied s commits after
              its fetch weighs discount**s before per-commit normalization
              (discount 1.0 = stale and fresh weigh equally).
    tau       server steps per version — scalar, or a (n_versions,) array
              for controller-driven piecewise-τ runs. The commit can never
              land before fetch + τ·t_server (unbalanced-update overlap).
    mask_rows optional (n_versions, M) availability override; defaults to
              the schedule's masks rows (cyclic). Used by the engine when a
              controller re-derives deadline drops mid-run.

    Deterministic in its inputs (the schedule already froze every random
    draw), and prefix-stable: two compilations agreeing on the first v
    versions of (tau, mask_rows) agree on the first v rows of every output
    — which is what lets a controller recompile the future without
    rewriting the past.
    """
    R, M = schedule.delays.shape
    V = int(n_versions)
    taus = np.full(V, tau, np.int64) if np.ndim(tau) == 0 else \
        np.asarray(tau, np.int64)
    if taus.shape != (V,):
        raise ValueError(f"tau_per_version shape {taus.shape} != ({V},)")
    if mask_rows is None:
        mask_rows = np.stack([schedule.masks[v % R] for v in range(V)])
    mask_rows = np.asarray(mask_rows, np.float32)
    if mask_rows.shape != (V, M):
        raise ValueError(f"mask_rows shape {mask_rows.shape} != ({V}, {M})")
    comm = np.full(M, schedule.t_comm, np.float64)
    if schedule.t_comm_scale is not None:
        comm = schedule.t_comm * np.asarray(schedule.t_comm_scale, np.float64)
    cohorts = (schedule.population.cohort_ids()
               if getattr(schedule, "population", None) is not None
               else np.zeros(M, np.int64))

    start_mask = np.zeros((V, M), np.float32)
    apply_w = np.zeros((V, M), np.float32)
    staleness_m = np.full((V, M), -1, np.int64)
    commit_times = np.zeros(V, np.float64)
    durations = np.zeros(V, np.float64)
    quorum_wait = np.zeros(V, np.float64)
    applied_n = np.zeros(V, np.int64)
    events = []                       # (arrival, client, origin, stale, commit)

    t = 0.0
    pending: Dict[int, Tuple[float, int]] = {}   # client -> (arrival, origin)
    for v in range(V):
        # broadcast: every idle client on this version's mask fetches the
        # just-committed params and starts a fresh contribution
        for m in range(M):
            if mask_rows[v, m] > 0 and m not in pending:
                pending[m] = (t + schedule.delays[v % R, m] + comm[m], v)
                start_mask[v, m] = 1.0
        arrivals = sorted(a for a, _ in pending.values())
        k = len(arrivals) if quorum <= 0 else min(quorum, len(arrivals))
        q_arrival = arrivals[k - 1] if k else t
        quorum_wait[v] = max(q_arrival - t, 0.0)
        c_time = max(q_arrival, t + float(taus[v]) * schedule.t_server)
        # fold in everything delivered by the commit moment
        w = np.zeros(M, np.float64)
        for m in sorted(pending):
            arr, origin = pending[m]
            if arr <= c_time:
                s = v - origin
                w[m] = discount ** s
                staleness_m[v, m] = s
                events.append((arr, m, origin, s, v))
                del pending[m]
        tot = w.sum()
        if tot > 0:
            w = w / tot
        apply_w[v] = w.astype(np.float32)
        applied_n[v] = int((w > 0).sum())
        commit_times[v] = c_time
        durations[v] = c_time - t
        t = c_time
    # contributions still in flight at the horizon: delivered to nobody
    for m in sorted(pending):
        arr, origin = pending[m]
        events.append((arr, m, origin, -1, -1))

    ev = np.array(events, np.float64).reshape(-1, 5)
    order = np.lexsort((ev[:, 1], ev[:, 0]))       # arrival, then client id
    ev = ev[order]
    client_id = ev[:, 1].astype(np.int64)
    return Timeline(
        arrival_time=ev[:, 0], client_id=client_id,
        cohort_id=cohorts[client_id],
        round_of_origin=ev[:, 2].astype(np.int64),
        staleness=ev[:, 3].astype(np.int64),
        commit_idx=ev[:, 4].astype(np.int64),
        start_mask=start_mask, apply_w=apply_w, staleness_m=staleness_m,
        commit_times=commit_times, durations=durations,
        quorum_wait=quorum_wait, applied=applied_n,
        quorum=int(quorum), discount=float(discount), tau_per_version=taus)


def quorum_round_time(delays: np.ndarray, mask: np.ndarray, t_server: float,
                      tau: int, quorum: int = 0, t_comm: float = 0.0,
                      t_comm_scale: Optional[np.ndarray] = None) -> float:
    """Steady-state single-version time under quorum commits: the K-th
    smallest active arrival (delay + uplink), floored by the server's
    τ·t_server. The compiled timeline is the exact account (it carries
    busy clients across versions); this is the per-row approximation an
    Algorithm.time_model can give without one."""
    comm = (np.full_like(delays, t_comm) if t_comm_scale is None
            else t_comm * np.asarray(t_comm_scale, np.float64))
    arrivals = np.sort((delays + comm)[np.asarray(mask) > 0])
    k = len(arrivals) if quorum <= 0 else min(quorum, len(arrivals))
    wait = float(arrivals[k - 1]) if k else 0.0
    return max(wait, tau * t_server)


# ---------------------------------------------------------------------------
# the jit'd per-version step: fixed-shape record store + quorum commit
# ---------------------------------------------------------------------------

def init_store(sfl: SFLConfig) -> Dict[str, jax.Array]:
    """The in-flight contribution buffer: one slot per client (a client
    computes at most one contribution at a time), each slot the replayable
    seed-record wire format of a full MU-SplitFed contribution — (τ, P)
    server records, the client (key, coeff) pair, and the fetch-time loss
    metric. Zero coeffs make an empty/consumed slot replay-inert."""
    M, T, P = sfl.n_clients, sfl.tau, sfl.n_perturbations
    return {
        "srv_keys": jnp.zeros((M, T, P, 2), jnp.uint32),
        "srv_coeffs": jnp.zeros((M, T, P), jnp.float32),
        "ukey": jnp.zeros((M, 2), jnp.uint32),
        "ccoeff": jnp.zeros((M,), jnp.float32),
        "loss0": jnp.zeros((M,), jnp.float32),
    }


def resize_store(store: Dict[str, jax.Array], tau: int) -> Dict[str, jax.Array]:
    """Re-shape the record store's τ axis after a controller re-plans τ
    (the store is jit state, so its shapes are static per executable).
    Growth zero-pads (inert records); shrink truncates the tail server
    records of still-in-flight stale contributions — an approximation on
    work that would have been staleness-discounted anyway."""
    old = store["srv_keys"].shape[1]
    if tau == old:
        return store
    out = dict(store)
    if tau > old:
        pad = [(0, 0), (0, tau - old)] + [(0, 0)]
        out["srv_keys"] = jnp.pad(store["srv_keys"], pad + [(0, 0)])
        out["srv_coeffs"] = jnp.pad(store["srv_coeffs"], pad)
    else:
        out["srv_keys"] = store["srv_keys"][:, :tau]
        out["srv_coeffs"] = store["srv_coeffs"][:, :tau]
    return out


def async_mu_splitfed_step(cfg: ModelConfig, sfl: SFLConfig, params: Params,
                           store: Dict[str, jax.Array], batches,
                           start_mask: jax.Array, apply_w: jax.Array,
                           version_key, *, replay: str = "auto",
                           eval_loss: bool = True):
    """One server version of semi-async MU-SplitFed (pure/jit-able).

    start_mask (M,) selects the clients that fetch the CURRENT params and
    compute a fresh contribution this version (their records overwrite
    their store slot — the timeline guarantees the old slot was already
    committed). apply_w (M,) are the normalized staleness-discounted
    weights of this version's quorum commit: the whole store is replayed
    in one fused sweep with per-record coefficients c·η_g·w_m, so slots
    with w=0 (in-flight or idle) contribute exactly zero. Client compute
    happens at fetch time by construction, which is what makes stale
    records genuinely stale: they were generated against the params of
    their round_of_origin.
    """
    M = sfl.n_clients
    xc, xs = split_params(cfg, params, sfl.cut_units)
    mkeys = jax.vmap(lambda i: jax.random.fold_in(version_key, i))(
        jnp.arange(M))
    out = jax.vmap(lambda b, k: _client_round(cfg, sfl, xc, xs, b, k,
                                              eval_loss, replay)
                   )(batches, mkeys)
    fresh = {"srv_keys": out["srv_keys"], "srv_coeffs": out["srv_coeffs"],
             "ukey": out["ukey"], "ccoeff": out["ccoeff"],
             "loss0": out["loss0"]}

    def sel(new, old):
        m = start_mask.reshape((M,) + (1,) * (new.ndim - 1))
        return jnp.where(m > 0, new, old)

    store = jax.tree.map(sel, fresh, store)
    w = (sfl.lr_global * apply_w).astype(jnp.float32)
    xs_new = zo.replay_weighted_records(xs, store["srv_keys"],
                                        store["srv_coeffs"], w,
                                        sfl.perturbation_dist, impl=replay)
    xc_new = zo.replay_weighted_records(xc, store["ukey"], store["ccoeff"],
                                        w, sfl.perturbation_dist, impl=replay)
    metrics = {"loss": store["loss0"]}
    return merge_params(cfg, xc_new, xs_new), store, metrics
