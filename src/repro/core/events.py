"""Event-driven semi-async execution: arrival-ordered server updates with
quorum aggregation and staleness-weighted seed replay.

The engine's sync modes run a hard round barrier: every server commit waits
for the full per-round mask, so one slow cohort stalls the fleet — exactly
the synchronization cost the paper identifies. This module is the execution
substrate that drops the barrier while keeping every device-side shape
fixed:

  compile_timeline   a host-side discrete-event simulator over the existing
                     straggler.Schedule. Clients fetch the newest params at
                     each server-version broadcast and deliver their
                     contribution delay + uplink later; the server COMMITS
                     version v+1 as soon as a quorum of K contributions has
                     arrived (FedBuff-style semi-async; K=0 means "all
                     pending" — the synchronous barrier). Contributions
                     that miss the commit are NOT dropped: they fold into a
                     later commit with staleness s = commits missed, and a
                     discount^s weight. The product is a globally
                     arrival-ordered, fixed-shape event stream — stacked
                     (E,) arrays of (arrival_time, client_id, cohort_id,
                     round_of_origin, staleness) — plus its per-version
                     compiled form ((V, M) start/apply matrices and (V,)
                     commit times) that the engine scans as *data*.
  async_round_fn     the jit'd per-version step. Because every MU-SplitFed
                     contribution is replayable seed-records ((key, coeff)
                     pairs — zo.py's wire format), the whole in-flight
                     buffer is a fixed (M, τ, P) record store carried as
                     engine state: committing a quorum is one
                     zo.replay_weighted_records call with the timeline's
                     staleness-discounted weights scaled per record — no
                     new kernel, the fused one-sweep replay path (ladder
                     v4) applies the buffer regardless of which versions
                     its records came from.

Semantics (the "semi" in semi-async): client work is version-aligned —
a client only fetches params and starts a fresh contribution at a version
broadcast (the commit it was applied in, or later), never mid-version; the
server is fully event-driven and commits on quorum arrival. With quorum
K=0/K>=M and discount 1.0 every version's buffer is exactly the sync
round's active set with the sync weights, so mode='async' reproduces
mode='scan' (tests/test_events.py gates <=1e-5).

Wall-clock model: version duration = max(K-th pending arrival, τ·t_server)
— the unbalanced server steps still overlap the wait (Eq. 12) — where an
arrival is fetch_time + delay + t_comm·uplink_scale. Note this charges the
uplink per arrival (the sync models charge the slowest active uplink once
per round), which is the natural accounting once arrivals, not round
maxima, pace the server.

Two timeline backends share these semantics (SFLConfig.timeline):

  'dense'   compile_timeline's (V, M) rows + the (M, τ, P) per-client
            store — the small-M reference implementation.
  'sparse'  the streaming path (TimelineStream / SparseRows below): a
            heap-based DES emits (V, k_max) scatter/gather commit batches
            chunk-by-chunk over a bounded arrival-slot ring store, so host
            memory is O(k_max · chunk) + O(M) instead of O(V · M) and the
            "K ≪ M arrivals per commit" fleet regime is simulable.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import zo
from repro.core.faults import (OUT_CORRUPT, OUT_CRASH, OUT_DELIVER,
                               OUT_LOST, STALE_CORRUPT, STALE_CRASH,
                               STALE_LOST, FaultPlan, ResolvedFaults)
from repro.obs.trace import span
from repro.core.population import AvailRow
from repro.core.splitfed import _client_round
from repro.models import merge_params, split_params

Params = Any

__all__ = ["Timeline", "compile_timeline", "quorum_round_time",
           "init_store", "resize_store", "async_mu_splitfed_step",
           "SparseRows", "SparseTimeline", "TimelineStream",
           "compile_sparse_timeline", "resolve_store_geometry",
           "async_mu_splitfed_sparse_step", "QuorumStallError"]


class QuorumStallError(ValueError):
    """A version's quorum can never fill and no quorum_timeout is set."""


def _resolve_faults(schedule, faults) -> Optional[ResolvedFaults]:
    """FaultPlan -> per-client rates keyed on the schedule's seed; None
    (or an inert plan) -> None, so callers can gate every fault branch on
    a single ``is not None`` and the zero-fault path stays byte-identical."""
    if faults is None:
        return None
    if isinstance(faults, ResolvedFaults):
        return faults
    if not faults.any():
        return None
    return faults.resolve(schedule.n_clients,
                          getattr(schedule, "population", None),
                          getattr(schedule, "seed", 0))


def _stall_error(v: int, n_deliverable: int, quorum: int) -> QuorumStallError:
    return QuorumStallError(
        f"quorum stall at version {v}: only {n_deliverable} deliverable "
        f"contribution(s) pending against quorum={quorum} under an active "
        "fault plan — the commit would silently under-fill forever. Set "
        "quorum_timeout (SFLConfig.quorum_timeout / --quorum-timeout) to "
        "commit with whatever arrived by the deadline, or lower the "
        "quorum/fault rates.")


# ---------------------------------------------------------------------------
# the event compiler (host-side discrete-event simulation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Timeline:
    """A compiled semi-async execution trace.

    Flat, globally arrival-ordered event view — one row per delivered
    contribution, all (E,):

      arrival_time     absolute simulated delivery time
      client_id        which client delivered
      cohort_id        its population cohort (0 for scalar fleets)
      round_of_origin  the version whose params/batch/mask it consumed
      staleness        commits between fetch and apply (>=1 means it missed
                       its own version's quorum and folded forward)
      commit_idx       the version commit that applied it (-1: still in
                       flight when the horizon ended — never applied)

    Per-version compiled form the engine scans as data:

      start_mask   (V, M) 1.0 where a client fetches params and begins a
                   fresh contribution at this version's broadcast
      apply_w      (V, M) normalized staleness-discounted aggregation
                   weights of the records this commit applies (rows sum to
                   1, or 0 for an empty commit); 0 = not applied
      staleness_m  (V, M) staleness of the applied record (-1 = not applied)
      commit_times (V,)   absolute commit completion times
      durations    (V,)   per-version wall-clock (commit_times diffs)
      quorum_wait  (V,)   time from broadcast to the quorum arrival, BEFORE
                   the τ·t_server server floor — what an adaptive-τ
                   controller should fill with server steps (Eq. 12)
      applied      (V,)   contributions folded into each commit
    """
    arrival_time: np.ndarray
    client_id: np.ndarray
    cohort_id: np.ndarray
    round_of_origin: np.ndarray
    staleness: np.ndarray
    commit_idx: np.ndarray
    start_mask: np.ndarray
    apply_w: np.ndarray
    staleness_m: np.ndarray
    commit_times: np.ndarray
    durations: np.ndarray
    quorum_wait: np.ndarray
    applied: np.ndarray
    quorum: int
    discount: float
    tau_per_version: np.ndarray
    # fault / degradation accounting, all (V,) — zero everywhere when the
    # run had no FaultPlan (started == dispatches incl. faulted fetches;
    # timeouts flags commits forced by the quorum_timeout deadline)
    started: Optional[np.ndarray] = None
    crashed: Optional[np.ndarray] = None
    lost: Optional[np.ndarray] = None
    corrupt: Optional[np.ndarray] = None
    dups: Optional[np.ndarray] = None
    retries: Optional[np.ndarray] = None
    timeouts: Optional[np.ndarray] = None

    @property
    def n_versions(self) -> int:
        return self.start_mask.shape[0]

    @property
    def n_clients(self) -> int:
        return self.start_mask.shape[1]

    @property
    def n_events(self) -> int:
        return self.arrival_time.shape[0]


def compile_timeline(schedule, n_versions: int, *, quorum=0,
                     discount: float = 1.0, tau=1,
                     mask_rows: Optional[np.ndarray] = None,
                     faults=None, quorum_timeout: float = 0.0,
                     max_retries: int = 3) -> Timeline:
    """Compile ``n_versions`` semi-async server versions from a Schedule.

    quorum    K: commit as soon as K of the pending contributions have
              arrived (K<=0 or K>=pending: wait for all — the sync
              barrier). A commit folds in *everything* delivered by the
              commit moment, quorum members and opportunistic extras alike.
              Scalar, or a (n_versions,) array for controller-driven
              piecewise-quorum runs (AdaptiveQuorum).
    discount  staleness weight base: a contribution applied s commits after
              its fetch weighs discount**s before per-commit normalization
              (discount 1.0 = stale and fresh weigh equally).
    tau       server steps per version — scalar, or a (n_versions,) array
              for controller-driven piecewise-τ runs. The commit can never
              land before fetch + τ·t_server (unbalanced-update overlap).
    mask_rows optional (n_versions, M) availability override; defaults to
              the schedule's masks rows (cyclic). Used by the engine when a
              controller re-derives deadline drops mid-run.
    faults    FaultPlan (or pre-resolved ResolvedFaults) perturbing the
              event stream — crash-after-fetch, lossy delivery with up to
              ``max_retries`` retransmissions, duplication (deduped by
              (client, round_of_origin) — one in-flight record per client),
              checksum-dropped corruption. None / inert plan: the code
              path below is byte-identical to the pre-fault engine.
    quorum_timeout  graceful-degradation deadline: a commit with a quorum
              that hasn't filled by ``t + quorum_timeout`` proceeds with
              however many contributions arrived (weights renormalized —
              never deadlocks). With faults active, an under-fillable
              quorum and no timeout raises QuorumStallError instead of
              silently committing thin versions forever.

    Deterministic in its inputs (the schedule already froze every random
    draw; fault draws are counter-hashed on (seed, lane, version, client)),
    and prefix-stable: two compilations agreeing on the first v versions
    of (tau, quorum, mask_rows) agree on the first v rows of every output
    — which is what lets a controller recompile the future without
    rewriting the past.
    """
    R, M = schedule.delays.shape
    V = int(n_versions)
    taus = np.full(V, tau, np.int64) if np.ndim(tau) == 0 else \
        np.asarray(tau, np.int64)
    if taus.shape != (V,):
        raise ValueError(f"tau_per_version shape {taus.shape} != ({V},)")
    quorums = np.full(V, quorum, np.int64) if np.ndim(quorum) == 0 else \
        np.asarray(quorum, np.int64)
    if quorums.shape != (V,):
        raise ValueError(
            f"quorum_per_version shape {quorums.shape} != ({V},)")
    if mask_rows is None:
        mask_rows = (np.stack([schedule.masks[v % R] for v in range(V)])
                     if V else np.zeros((0, M), np.float32))
    mask_rows = np.asarray(mask_rows, np.float32)
    if mask_rows.shape != (V, M):
        raise ValueError(f"mask_rows shape {mask_rows.shape} != ({V}, {M})")
    comm = np.full(M, schedule.t_comm, np.float64)
    if schedule.t_comm_scale is not None:
        comm = schedule.t_comm * np.asarray(schedule.t_comm_scale, np.float64)
    cohorts = (schedule.population.cohort_ids()
               if getattr(schedule, "population", None) is not None
               else np.zeros(M, np.int64))
    rf = _resolve_faults(schedule, faults)

    start_mask = np.zeros((V, M), np.float32)
    apply_w = np.zeros((V, M), np.float32)
    staleness_m = np.full((V, M), -1, np.int64)
    commit_times = np.zeros(V, np.float64)
    durations = np.zeros(V, np.float64)
    quorum_wait = np.zeros(V, np.float64)
    applied_n = np.zeros(V, np.int64)
    started_n = np.zeros(V, np.int64)
    crashed_n = np.zeros(V, np.int64)
    lost_n = np.zeros(V, np.int64)
    corrupt_n = np.zeros(V, np.int64)
    dup_n = np.zeros(V, np.int64)
    retry_n = np.zeros(V, np.int64)
    timeout_n = np.zeros(V, np.int64)
    events = []                       # (arrival, client, origin, stale, commit)

    t = 0.0
    pending: Dict[int, Tuple[float, int]] = {}   # client -> (arrival, origin)
    recovering: Dict[int, float] = {}   # crashed/dropped client -> idle time
    streaks = np.zeros(M, np.int64) if rf is not None else None
    for v in range(V):
        if rf is not None and recovering:
            for m in [m for m, rdy in recovering.items() if rdy <= t]:
                del recovering[m]
        # broadcast: every idle client on this version's mask fetches the
        # just-committed params and starts a fresh contribution
        if rf is None:
            for m in range(M):
                if mask_rows[v, m] > 0 and m not in pending:
                    pending[m] = (t + schedule.delays[v % R, m] + comm[m], v)
                    start_mask[v, m] = 1.0
        else:
            starters = [m for m in range(M)
                        if mask_rows[v, m] > 0 and m not in pending
                        and m not in recovering]
            started_n[v] = len(starters)
            if starters:
                sids = np.asarray(starters, np.int64)
                f = rf.dispatch_fates(v, sids, t,
                                      schedule.delays[v % R, sids],
                                      comm[sids], streaks, max_retries)
                retry_n[v] = int(f["retries"].sum())
                dup_n[v] = int(f["dup"].sum())
                for j, m in enumerate(starters):
                    out = int(f["outcome"][j])
                    if out == OUT_DELIVER:
                        pending[m] = (float(f["arrival"][j]), v)
                        start_mask[v, m] = 1.0
                        streaks[m] = 0
                        continue
                    recovering[m] = float(f["ready"][j])
                    if out == OUT_CRASH:
                        streaks[m] += 1
                        crashed_n[v] += 1
                        events.append((t, m, v, STALE_CRASH, -1))
                    elif out == OUT_LOST:
                        streaks[m] = 0
                        lost_n[v] += 1
                        events.append((float(f["ready"][j]), m, v,
                                       STALE_LOST, -1))
                    else:                      # corrupt: checksum drop
                        streaks[m] = 0
                        corrupt_n[v] += 1
                        events.append((float(f["arrival"][j]), m, v,
                                       STALE_CORRUPT, -1))
        q_req = int(quorums[v])
        arrivals = sorted(a for a, _ in pending.values())
        k = len(arrivals) if q_req <= 0 else min(q_req, len(arrivals))
        q_arrival = arrivals[k - 1] if k else t
        if q_req > 0 and quorum_timeout > 0:
            deadline = t + quorum_timeout
            if len(arrivals) < q_req or q_arrival > deadline:
                q_arrival = deadline            # degrade: commit what came
                timeout_n[v] = 1
        elif rf is not None and q_req > 0 and len(arrivals) < q_req:
            raise _stall_error(v, len(arrivals), q_req)
        quorum_wait[v] = max(q_arrival - t, 0.0)
        c_time = max(q_arrival, t + float(taus[v]) * schedule.t_server)
        # fold in everything delivered by the commit moment
        w = np.zeros(M, np.float64)
        for m in sorted(pending):
            arr, origin = pending[m]
            if arr <= c_time:
                s = v - origin
                w[m] = discount ** s
                staleness_m[v, m] = s
                events.append((arr, m, origin, s, v))
                del pending[m]
        tot = w.sum()
        if tot > 0:
            w = w / tot
        apply_w[v] = w.astype(np.float32)
        applied_n[v] = int((w > 0).sum())
        commit_times[v] = c_time
        durations[v] = c_time - t
        t = c_time
    if rf is None:
        started_n = start_mask.sum(axis=1).astype(np.int64)
    # contributions still in flight at the horizon: delivered to nobody
    for m in sorted(pending):
        arr, origin = pending[m]
        events.append((arr, m, origin, -1, -1))

    ev = (np.array(events, np.float64) if events
          else np.zeros((0, 5), np.float64))
    order = np.lexsort((ev[:, 1], ev[:, 0]))       # arrival, then client id
    ev = ev[order]
    client_id = ev[:, 1].astype(np.int64)
    return Timeline(
        arrival_time=ev[:, 0], client_id=client_id,
        cohort_id=cohorts[client_id],
        round_of_origin=ev[:, 2].astype(np.int64),
        staleness=ev[:, 3].astype(np.int64),
        commit_idx=ev[:, 4].astype(np.int64),
        start_mask=start_mask, apply_w=apply_w, staleness_m=staleness_m,
        commit_times=commit_times, durations=durations,
        quorum_wait=quorum_wait, applied=applied_n,
        quorum=int(quorums[0]) if V else
        (0 if np.ndim(quorum) else int(quorum)),
        discount=float(discount), tau_per_version=taus,
        started=started_n, crashed=crashed_n, lost=lost_n,
        corrupt=corrupt_n, dups=dup_n, retries=retry_n, timeouts=timeout_n)


def quorum_round_time(delays: np.ndarray, mask: np.ndarray, t_server: float,
                      tau: int, quorum: int = 0, t_comm: float = 0.0,
                      t_comm_scale: Optional[np.ndarray] = None) -> float:
    """Steady-state single-version time under quorum commits: the K-th
    smallest active arrival (delay + uplink), floored by the server's
    τ·t_server. The compiled timeline is the exact account (it carries
    busy clients across versions); this is the per-row approximation an
    Algorithm.time_model can give without one."""
    comm = (np.full_like(delays, t_comm) if t_comm_scale is None
            else t_comm * np.asarray(t_comm_scale, np.float64))
    arrivals = np.sort((delays + comm)[np.asarray(mask) > 0])
    k = len(arrivals) if quorum <= 0 else min(quorum, len(arrivals))
    wait = float(arrivals[k - 1]) if k else 0.0
    return max(wait, tau * t_server)


# ---------------------------------------------------------------------------
# sparse streaming timeline: heap DES -> (V, K) commit batches over an
# arrival-slot ring store
# ---------------------------------------------------------------------------
#
# The dense compiler above materializes (V, M) rows and re-sorts the whole
# pending set every version — fine as the small-M reference, O(V·M) host
# memory and O(V·M log M) time at fleet scale. The sparse path below keeps
# the *identical* commit semantics but emits only what a commit actually
# touches: per version, the <= K clients that start (scatter indices into a
# bounded ring of record slots) and the <= K contributions that apply
# (gather indices + staleness-discounted weights). The DES itself is a
# min-heap over arrivals with lazy deletion, so a version costs
# O(M) vectorized candidate scan + O((K + E_v) log M) heap work instead of
# a full sort, and the engine consumes the rows chunk-by-chunk while the
# device scans the previous chunk.
#
# Equivalence contract (gated in tests + bench_timeline --smoke): with
# k_max >= M and capacity >= M there is no truncation and no eviction, and
# SparseTimeline.densify() reproduces compile_timeline field-for-field;
# the engine's sparse loss trajectory then matches the dense async path.


def resolve_store_geometry(sfl: SFLConfig) -> Tuple[int, int]:
    """(k_max, ring_capacity) for timeline='sparse'.

    k_max bounds both the per-version start batch (fresh fetches admitted
    at a broadcast) and the apply batch (records gathered per commit);
    ring_capacity bounds the in-flight record store. Autos: with quorum=0
    both default to M (every client can be in flight — exactly the dense
    store, so the paths are bit-equivalent); with a quorum, k_max covers
    the quorum plus opportunistic extras (4x, floor 16) and the ring holds
    a staleness window of 8 commit batches. Neither ever exceeds M: a
    client carries at most one in-flight contribution.
    """
    M = int(sfl.n_clients)
    k = int(sfl.k_max)
    if k <= 0:
        k = M if sfl.quorum <= 0 else min(M, max(4 * int(sfl.quorum), 16))
    k = min(k, M)
    cap = int(sfl.ring_capacity)
    if cap <= 0:
        cap = M if sfl.quorum <= 0 else min(M, 8 * k)
    return k, min(max(cap, k), M)


class _CohortIdleIndex:
    """Per-cohort idle-client index: a virgin-range pointer plus a
    recycled-id min-heap per cohort, with exact per-cohort idle counters.

    Replaces the DES's O(M) ``flatnonzero((mask > 0) & ~busy)`` candidate
    scan: selection walks cohorts in client-id order, admitting up to
    k_max idle available clients by taking the min of the cohort's
    never-yet-consumed ascending range [virgin, hi) and its heap of
    recycled (previously finished) ids — O(K·log W + A_v) per version,
    where W is the in-flight window and A_v the size of the version's
    sparse availability records. Init is O(#cohorts), never O(M): the
    virgin range is two integers, and the heap only ever holds ids the
    pointer has already passed (``finish`` guards the push), so the
    min-of-union pop order is globally ascending. Heap entries are lazily
    invalidated (the busy vector is the truth); duplicates pop
    consecutively and are dropped; a busy id under the pointer is skipped
    (its eventual ``finish`` re-adds it). Bit-exact with the dense scan:
    cohorts are contiguous ascending id ranges, so admission order is
    ascending client id, and the idle counters make the skipped-candidate
    count exact without enumeration.
    """

    def __init__(self, bounds: Sequence[Tuple[int, int]]):
        self.bounds = [(int(lo), int(hi)) for lo, hi in bounds]
        self.virgin = [lo for lo, _ in self.bounds]
        self.heaps: List[List[int]] = [[] for _ in self.bounds]
        self.n_idle = [hi - lo for lo, hi in self.bounds]
        self._his = [hi for _, hi in self.bounds]

    def cohort_of(self, m: int) -> int:
        return bisect.bisect_right(self._his, m)

    def start(self, m: int) -> None:
        """Client m went busy (any heap entry for it goes stale)."""
        self.n_idle[self.cohort_of(m)] -= 1

    def start_batch(self, admitted: List[int]) -> None:
        """Admitted ids (ascending) went busy — one counter update per
        cohort instead of one per client."""
        n_idle, lo_i, n = self.n_idle, 0, len(admitted)
        for c, hi in enumerate(self._his):
            if lo_i >= n:
                break
            hi_i = bisect.bisect_left(admitted, hi, lo_i)
            if hi_i != lo_i:
                n_idle[c] -= hi_i - lo_i
                lo_i = hi_i

    def finish(self, m: int) -> None:
        """Client m went idle (commit or eviction)."""
        c = self.cohort_of(m)
        if m < self.virgin[c]:          # else still covered by the range
            heapq.heappush(self.heaps[c], m)
        self.n_idle[c] += 1

    def finish_batch(self, ms: Sequence[int]) -> None:
        """Clients went idle (commit or eviction), arbitrary order."""
        his, virgin = self._his, self.virgin
        heaps, n_idle = self.heaps, self.n_idle
        push, br = heapq.heappush, bisect.bisect_right
        for m in ms:
            c = br(his, m)
            n_idle[c] += 1
            if m < virgin[c]:           # else still covered by the range
                push(heaps[c], m)

    def select(self, avail: AvailRow, busy: np.ndarray,
               k_max: int) -> Tuple[List[int], int]:
        """(admitted ids, total candidate count) for one broadcast.

        Admitted = the first k_max idle available clients in ascending id
        order — exactly ``flatnonzero((mask > 0) & ~busy)[:k_max]``. The
        total count covers ALL cohorts (the skipped statistic), via the
        idle counters / sparse rows, never a fleet scan.
        """
        admitted: List[int] = []
        total = 0
        for c, kind in enumerate(avail.kinds):
            if kind == "none":
                continue
            need = k_max - len(admitted)
            if kind == "ids":
                ids = avail.ids[c]
                idle = ids[~busy[ids]]
                total += int(idle.size)
                if need > 0:
                    admitted.extend(idle[:need].tolist())
                continue                # index untouched (lazy staleness)
            if kind == "not_ids":
                down = avail.ids[c]
                down_idle = int((~busy[down]).sum()) if down.size else 0
                total += self.n_idle[c] - down_idle
            else:                       # 'all'
                total += self.n_idle[c]
            heap = self.heaps[c]
            down = avail.down_set(c) if kind == "not_ids" else ()
            hi = self.bounds[c][1]
            nxt = self.virgin[c]
            deferred: List[int] = []    # idle but unavailable: keep them
            last = -1
            while need > 0:
                if heap and (nxt >= hi or heap[0] < nxt):
                    m = heapq.heappop(heap)
                    if m == last:       # duplicate copy of the same entry
                        continue
                    last = m
                    if busy[m]:         # stale entry (lazy deletion)
                        continue
                elif nxt < hi:
                    m = nxt
                    nxt += 1
                    if busy[m]:         # started via an 'ids' row; its
                        continue        # finish() re-adds it to the heap
                else:
                    break
                if m in down:
                    deferred.append(m)
                    continue
                admitted.append(m)
                need -= 1
            self.virgin[c] = nxt
            for m in deferred:
                heapq.heappush(heap, m)
        return admitted, total


class _VStep(NamedTuple):
    """One simulated version, ragged (host-side only)."""
    start_clients: List[int]
    start_slots: List[int]
    apply_clients: List[int]
    apply_slots: List[int]
    apply_stales: List[int]
    apply_ws: List[float]
    commit_time: float
    duration: float
    quorum_wait: float
    evicted: int
    skipped: int
    # fault accounting (all zero on the zero-fault path); ``started``
    # counts every dispatch including faulted fetches, so
    # started == len(start_clients) + crashed + lost + corrupt
    started: int = 0
    crashed: int = 0
    lost: int = 0
    corrupt: int = 0
    dups: int = 0
    retries: int = 0
    timed_out: int = 0


class _EventSim:
    """The discrete-event core of the sparse timeline.

    State is slot-indexed over the ring: (capacity,) arrays of arrival
    time, occupying client (-1 = free slot), version of origin, and a
    monotone start counter (eviction order = start order). Admissions
    write a batch of slots per version (lowest free slots first, so
    capacity >= M degenerates to the dense one-slot-per-client layout and
    never evicts); commit selection is one lexsort by (arrival, client)
    over the <= capacity pending slots — no fleet-width pass anywhere.
    Candidate selection is driven by a _CohortIdleIndex over the
    population's cohort ranges (O(K·log W + A_v) per version), never an
    O(M) scan. Deterministic and prefix-stable in exactly the dense
    compiler's sense: same (quorum, discount, taus, masks) prefix ->
    same rows.

    ``step`` takes the availability row as either a dense (M,) mask (the
    bit-exact reference adapter, O(M) to bucket) or an AvailRow (the
    streaming mask protocol — sub-O(M)); delays as a dense (M,) row or a
    ``delays_for(ids)`` callable evaluated only on the admitted clients.
    """

    def __init__(self, n_clients: int, comm: np.ndarray, t_server: float,
                 *, quorum: int, discount: float, k_max: int,
                 capacity: int, collect_events: bool = False,
                 cohort_bounds: Optional[Sequence[Tuple[int, int]]] = None,
                 faults: Optional[ResolvedFaults] = None,
                 quorum_timeout: float = 0.0, max_retries: int = 3):
        self.M = int(n_clients)
        self.comm = np.asarray(comm, np.float64)
        self.t_server = float(t_server)
        self.quorum = int(quorum)
        self.discount = float(discount)
        self.k_max = int(k_max)
        self.capacity = int(capacity)
        self.quorum_timeout = float(quorum_timeout)
        self.max_retries = int(max_retries)
        self.faults = faults
        self.t = 0.0
        self.v = 0
        self._ord = 0
        # the ring, slot-indexed: client -1 marks a free slot
        self.slot_arr = np.zeros(self.capacity, np.float64)
        self.slot_client = np.full(self.capacity, -1, np.int64)
        self.slot_origin = np.zeros(self.capacity, np.int64)
        self.slot_ord = np.zeros(self.capacity, np.int64)
        self.busy = np.zeros(self.M, bool)
        self.idle = _CohortIdleIndex(cohort_bounds or [(0, self.M)])
        self._finished: List[int] = []  # drops awaiting the per-step flush
        if faults is not None:
            # crashed/dropped clients parked until their re-dispatch time,
            # and per-client consecutive-crash streaks (backoff exponent)
            self._recovering: List[Tuple[float, int]] = []
            self._streaks = np.zeros(self.M, np.int64)
        self.events: Optional[List[Tuple[float, int, int, int, int]]] = \
            [] if collect_events else None

    def step(self, delay_row, mask_row, tau: int,
             quorum: Optional[int] = None) -> _VStep:
        t, v = self.t, self.v
        rf = self.faults
        if rf is not None and self._recovering:
            # fault-freed clients whose backoff/drop time has passed
            # re-enter the idle index before this broadcast
            rec, freed = self._recovering, []
            while rec and rec[0][0] <= t:
                freed.append(heapq.heappop(rec)[1])
            if freed:
                self.busy[np.asarray(freed, np.int64)] = False
                self.idle.finish_batch(freed)
        # broadcast: idle clients on the mask fetch and start, in client-id
        # order (the dense compiler's iteration order), admitted up to the
        # k_max batch width; the rest are skipped, not deferred — they may
        # start at a later broadcast whose mask includes them
        avail = (mask_row if isinstance(mask_row, AvailRow) else
                 AvailRow.from_mask(mask_row, self.idle.bounds))
        admitted, n_cand = self.idle.select(avail, self.busy, self.k_max)
        skipped = n_cand - len(admitted)
        adm = np.asarray(admitted, np.int64)
        delays = (np.asarray(delay_row(adm), np.float64) if callable(delay_row)
                  else np.asarray(delay_row)[adm])
        self.busy[adm] = True           # evictions below re-clear theirs
        self.idle.start_batch(admitted)
        n_started = len(admitted)
        crashed = lost = corrupt = dups = retries = 0
        if rf is not None and n_started:
            f = rf.dispatch_fates(v, adm, t, delays, self.comm[adm],
                                  self._streaks, self.max_retries)
            out = f["outcome"]
            dups = int(f["dup"].sum())
            retries = int(f["retries"].sum())
            crashed = int((out == OUT_CRASH).sum())
            lost = int((out == OUT_LOST).sum())
            corrupt = int((out == OUT_CORRUPT).sum())
            self._streaks[adm[out != OUT_CRASH]] = 0
            if crashed or lost or corrupt:
                self._streaks[adm[out == OUT_CRASH]] += 1
                for j in np.flatnonzero(out != OUT_DELIVER).tolist():
                    m = int(adm[j])
                    # stays busy (no slot) until its re-dispatch time
                    heapq.heappush(self._recovering,
                                   (float(f["ready"][j]), m))
                    if self.events is not None:
                        o = int(out[j])
                        if o == OUT_CRASH:
                            self.events.append((t, m, v, STALE_CRASH, -1))
                        elif o == OUT_LOST:
                            self.events.append((float(f["ready"][j]), m, v,
                                                STALE_LOST, -1))
                        else:
                            self.events.append((float(f["arrival"][j]), m,
                                                v, STALE_CORRUPT, -1))
                keep = out == OUT_DELIVER
                adm = adm[keep]
                admitted = adm.tolist()
                arrs = f["arrival"][keep]
            else:
                arrs = f["arrival"]
        else:
            arrs = t + delays + self.comm[adm]
        n_admit = len(admitted)
        free_idx = np.flatnonzero(self.slot_client < 0)
        evicted = 0
        if n_admit <= free_idx.size:
            # common path: batch-assign the lowest free slots in admitted
            # (= ascending client id) order — exactly the sequential
            # pop-lowest-slot assignment when no eviction interleaves
            slots = free_idx[:n_admit]
            self.slot_arr[slots] = arrs
            self.slot_client[slots] = adm
            self.slot_origin[slots] = v
            self.slot_ord[slots] = self._ord + np.arange(n_admit)
            self._ord += n_admit
        else:
            # ring pressure: interleave evictions sequentially — each
            # admitted client takes the lowest slot free at that moment,
            # evicting the oldest-started in-flight contribution when none
            # is (it never applies — counted, never silent)
            free_heap = free_idx.tolist()   # ascending => a valid heap
            slot_list: List[int] = []
            for m, arr in zip(admitted, arrs.tolist()):
                if not free_heap:
                    valid = np.flatnonzero(self.slot_client >= 0)
                    es = int(valid[np.argmin(self.slot_ord[valid])])
                    em = int(self.slot_client[es])
                    self.slot_client[es] = -1
                    self.busy[em] = False
                    self._finished.append(em)
                    if self.events is not None:
                        self.events.append((float(self.slot_arr[es]), em,
                                            int(self.slot_origin[es]),
                                            -1, -1))
                    evicted += 1
                    heapq.heappush(free_heap, es)
                slot = heapq.heappop(free_heap)
                self.slot_arr[slot] = arr
                self.slot_client[slot] = m
                self.slot_origin[slot] = v
                self.slot_ord[slot] = self._ord
                self._ord += 1
                slot_list.append(slot)
            slots = np.asarray(slot_list, np.int64)
        # quorum: the k earliest pending arrivals, ties broken by client id
        # (the arrival heap's pop order) — one lexsort over <= capacity
        # slots; the k-th is the quorum arrival
        q_req = self.quorum if quorum is None else int(quorum)
        valid_idx = np.flatnonzero(self.slot_client >= 0)
        n_pend = valid_idx.size
        k = n_pend if q_req <= 0 else min(q_req, n_pend)
        if n_pend:
            va = self.slot_arr[valid_idx]
            order = np.lexsort((self.slot_client[valid_idx], va))
            sorted_slots = valid_idx[order]
            sa = va[order]
        q_arrival = float(sa[k - 1]) if k > 0 else t
        timed_out = 0
        if q_req > 0 and self.quorum_timeout > 0:
            deadline = t + self.quorum_timeout
            if n_pend < q_req or q_arrival > deadline:
                q_arrival = deadline            # degrade: commit what came
                timed_out = 1
        elif rf is not None and q_req > 0 and n_pend < q_req:
            raise _stall_error(v, n_pend, q_req)
        quorum_wait = max(q_arrival - t, 0.0) if (k > 0 or timed_out) else 0.0
        c_time = max(q_arrival, t + float(tau) * self.t_server)
        # opportunistic extras: everything else delivered by the commit,
        # up to the k_max batch width; overflow past the width (possible
        # when quorum > k_max) simply stays pending — it folds into a
        # later commit at discount**(staleness then), never dropped
        n_del = int(np.searchsorted(sa, c_time, side="right")) if n_pend \
            else 0
        n_take = min(n_del, self.k_max)
        take = sorted_slots[:n_take] if n_take else \
            np.zeros(0, np.int64)
        # apply in client-id order (dense: `for m in sorted(pending)`)
        ord2 = np.argsort(self.slot_client[take])
        take = take[ord2]
        clients = self.slot_client[take]
        stales = v - self.slot_origin[take]
        ws_arr = np.power(self.discount, stales.astype(np.float64))
        tot = float(np.sum(ws_arr)) if n_take else 0.0
        if tot > 0:
            ws_arr = ws_arr / tot
        if self.events is not None and n_take:
            arrs_t = self.slot_arr[take]
            origins = self.slot_origin[take]
            for j in range(n_take):
                self.events.append((float(arrs_t[j]), int(clients[j]),
                                    int(origins[j]), int(stales[j]), v))
        if n_take:
            self.slot_client[take] = -1
            self.busy[clients] = False
            self._finished.extend(clients.tolist())
        if self._finished:
            self.idle.finish_batch(self._finished)
            self._finished.clear()
        self.t, self.v = c_time, v + 1
        return _VStep(
            start_clients=admitted, start_slots=slots.tolist(),
            apply_clients=clients.tolist(),
            apply_slots=take.tolist(),
            apply_stales=stales.tolist(), apply_ws=ws_arr.tolist(),
            commit_time=c_time, duration=c_time - t,
            quorum_wait=quorum_wait, evicted=evicted, skipped=skipped,
            started=n_started, crashed=crashed, lost=lost, corrupt=corrupt,
            dups=dups, retries=retries, timed_out=timed_out)

    def finalize_events(self) -> List[Tuple[float, int, int, int, int]]:
        """Contributions still in flight at the horizon (delivered to
        nobody), appended to the collected event list."""
        assert self.events is not None
        valid = np.flatnonzero(self.slot_client >= 0)
        for s_i in valid[np.argsort(self.slot_client[valid])].tolist():
            self.events.append((float(self.slot_arr[s_i]),
                                int(self.slot_client[s_i]),
                                int(self.slot_origin[s_i]), -1, -1))
        return self.events


class SparseRows(NamedTuple):
    """(C, K)-padded sparse commit rows for C consecutive versions.

    Pad conventions are chosen for JAX's out-of-bounds semantics so the
    device step needs no masking: start_client / apply_client pad -1 (the
    step clips to 0 for key fold-in and batch gather — the row is inert
    because its slot/weight pads make it so); start_slot pads `capacity`
    (scatter mode='drop' discards the row); apply_slot pads `capacity`
    (gather clamps to the last slot, multiplied by apply_w's 0 pad).
    """
    start_client: np.ndarray     # (C, Ks) i64, pad -1
    start_slot: np.ndarray       # (C, Ks) i64, pad = capacity
    apply_client: np.ndarray     # (C, Ka) i64, pad -1
    apply_slot: np.ndarray       # (C, Ka) i64, pad = capacity
    apply_stale: np.ndarray      # (C, Ka) i64, pad -1
    apply_w: np.ndarray          # (C, Ka) f32, pad 0
    commit_times: np.ndarray     # (C,) f64
    durations: np.ndarray        # (C,) f64
    quorum_wait: np.ndarray      # (C,) f64
    applied: np.ndarray          # (C,) i64
    started: np.ndarray          # (C,) i64  dispatches incl. faulted
    evicted: np.ndarray          # (C,) i64
    skipped: np.ndarray          # (C,) i64
    # fault accounting (zero on the zero-fault path)
    crashed: np.ndarray = np.zeros(0, np.int64)    # (C,) i64
    lost: np.ndarray = np.zeros(0, np.int64)       # (C,) i64
    corrupt: np.ndarray = np.zeros(0, np.int64)    # (C,) i64
    dups: np.ndarray = np.zeros(0, np.int64)       # (C,) i64
    retries: np.ndarray = np.zeros(0, np.int64)    # (C,) i64
    timeouts: np.ndarray = np.zeros(0, np.int64)   # (C,) i64


def _pack_rows(steps: Sequence[_VStep], k_start: int, k_apply: int,
               capacity: int) -> SparseRows:
    C = len(steps)
    sc = np.full((C, k_start), -1, np.int64)
    ss = np.full((C, k_start), capacity, np.int64)
    ac = np.full((C, k_apply), -1, np.int64)
    asl = np.full((C, k_apply), capacity, np.int64)
    ast = np.full((C, k_apply), -1, np.int64)
    aw = np.zeros((C, k_apply), np.float32)
    for i, s in enumerate(steps):
        ns, na = len(s.start_clients), len(s.apply_clients)
        sc[i, :ns] = s.start_clients
        ss[i, :ns] = s.start_slots
        ac[i, :na] = s.apply_clients
        asl[i, :na] = s.apply_slots
        ast[i, :na] = s.apply_stales
        aw[i, :na] = np.asarray(s.apply_ws, np.float64).astype(np.float32) \
            if na else 0.0
    return SparseRows(
        start_client=sc, start_slot=ss, apply_client=ac, apply_slot=asl,
        apply_stale=ast, apply_w=aw,
        commit_times=np.array([s.commit_time for s in steps], np.float64),
        durations=np.array([s.duration for s in steps], np.float64),
        quorum_wait=np.array([s.quorum_wait for s in steps], np.float64),
        applied=np.array([len(s.apply_clients) for s in steps], np.int64),
        started=np.array([s.started for s in steps], np.int64),
        evicted=np.array([s.evicted for s in steps], np.int64),
        skipped=np.array([s.skipped for s in steps], np.int64),
        crashed=np.array([s.crashed for s in steps], np.int64),
        lost=np.array([s.lost for s in steps], np.int64),
        corrupt=np.array([s.corrupt for s in steps], np.int64),
        dups=np.array([s.dups for s in steps], np.int64),
        retries=np.array([s.retries for s in steps], np.int64),
        timeouts=np.array([s.timed_out for s in steps], np.int64))


def _comm_of(schedule) -> np.ndarray:
    comm = np.full(schedule.n_clients, schedule.t_comm, np.float64)
    if schedule.t_comm_scale is not None:
        comm = schedule.t_comm * np.asarray(schedule.t_comm_scale, np.float64)
    return comm


def _cohort_bounds_of(schedule) -> List[Tuple[int, int]]:
    pop = getattr(schedule, "population", None)
    if pop is None:
        return [(0, schedule.n_clients)]
    return [(s.start, s.stop) for s in pop.slices()]


class TimelineStream:
    """Chunk-streamed sparse timeline.

    The engine pulls ``take(C)`` (C, K) commit-batch rows while the device
    scans the previous chunk — the (V, ·) trace never materializes on the
    host. ``skip(n)`` advances the simulation without building rows (the
    engine replays the prefix on resume and on controller re-plans, which
    is what makes the stream prefix-stable in the dense compiler's sense:
    rebuild with the same knob prefix + skip(v) == the original stream at
    v, ring state included).

    taus may be a live (n_versions,) array a controller mutates for
    versions not yet taken; mask_row_fn(v) -> (M,) overrides the cyclic
    schedule masks (the engine uses it for deadline re-plans).

    ``schedule`` is a dense straggler.Schedule — or any lazy schedule
    speaking the streaming mask protocol (straggler.make_sparse_schedule):
    ``avail_row(r)`` AvailRows + ``delays_for(r, ids)`` keyed delays
    instead of materialized (R, M) rows, which is what lets the DES run
    million-client fleets without ever densifying the schedule.
    """

    def __init__(self, schedule, n_versions: int, *, quorum: int,
                 discount: float, taus, k_max: int, capacity: int,
                 mask_row_fn: Optional[Callable[[int], np.ndarray]] = None,
                 collect_events: bool = False, quorums=None,
                 faults=None, quorum_timeout: float = 0.0,
                 max_retries: int = 3):
        self.schedule = schedule
        self.R, self.M = schedule.n_rounds, schedule.n_clients
        self._lazy = not hasattr(schedule, "masks")
        self.n_versions = int(n_versions)
        self.taus = (np.full(self.n_versions, taus, np.int64)
                     if np.ndim(taus) == 0 else np.asarray(taus))
        if self.taus.shape != (self.n_versions,):
            raise ValueError(
                f"taus shape {self.taus.shape} != ({self.n_versions},)")
        # per-version quorum — a live array like taus (AdaptiveQuorum
        # mutates versions not yet taken); None = the scalar everywhere
        self.quorums = (np.full(self.n_versions, quorum, np.int64)
                        if quorums is None else np.asarray(quorums, np.int64))
        if self.quorums.shape != (self.n_versions,):
            raise ValueError(
                f"quorums shape {self.quorums.shape} != "
                f"({self.n_versions},)")
        self.k_max = int(k_max)
        self.capacity = int(capacity)
        self.mask_row_fn = mask_row_fn
        self.sim = _EventSim(
            self.M, _comm_of(schedule), schedule.t_server, quorum=quorum,
            discount=discount, k_max=k_max, capacity=capacity,
            collect_events=collect_events,
            cohort_bounds=_cohort_bounds_of(schedule),
            faults=_resolve_faults(schedule, faults),
            quorum_timeout=quorum_timeout, max_retries=max_retries)

    @property
    def v(self) -> int:
        return self.sim.v

    def _step(self) -> _VStep:
        v = self.sim.v
        if v >= self.n_versions:
            raise ValueError(f"stream exhausted at version {v}")
        r = v % self.R
        if self._lazy:
            mask = (self.mask_row_fn(v) if self.mask_row_fn is not None
                    else self.schedule.avail_row(r))
            delays = lambda ids: self.schedule.delays_for(r, ids)
        else:
            mask = (self.mask_row_fn(v) if self.mask_row_fn is not None
                    else self.schedule.masks[r])
            delays = self.schedule.delays[r]
        return self.sim.step(delays, mask, int(self.taus[v]),
                             quorum=int(self.quorums[v]))

    def skip(self, n: int) -> None:
        for _ in range(int(n)):
            self._step()

    def take(self, n: int) -> SparseRows:
        n = min(int(n), self.n_versions - self.sim.v)
        with span("events.stream_take", v=self.sim.v, n=n):
            return _pack_rows([self._step() for _ in range(n)],
                              self.k_max, self.k_max, self.capacity)


@dataclasses.dataclass(frozen=True)
class SparseTimeline:
    """A fully-compiled sparse trace: SparseRows over all V versions plus
    the flat arrival-ordered event view (same columns as Timeline) and the
    run config. ``densify()`` expands back to the dense Timeline — the
    equivalence gate compares that against compile_timeline field-for-
    field (exact when nothing was truncated or evicted, i.e. k_max and
    capacity >= M)."""
    rows: SparseRows
    arrival_time: np.ndarray
    client_id: np.ndarray
    cohort_id: np.ndarray
    round_of_origin: np.ndarray
    staleness: np.ndarray
    commit_idx: np.ndarray
    quorum: int
    discount: float
    tau_per_version: np.ndarray
    n_clients: int
    capacity: int

    @property
    def n_versions(self) -> int:
        return self.rows.start_client.shape[0]

    @property
    def n_events(self) -> int:
        return self.arrival_time.shape[0]

    def densify(self) -> Timeline:
        V, M, r = self.n_versions, self.n_clients, self.rows
        start_mask = np.zeros((V, M), np.float32)
        apply_w = np.zeros((V, M), np.float32)
        staleness_m = np.full((V, M), -1, np.int64)
        for v in range(V):
            sc = r.start_client[v]
            start_mask[v, sc[sc >= 0]] = 1.0
            live = r.apply_client[v] >= 0
            ac = r.apply_client[v][live]
            apply_w[v, ac] = r.apply_w[v][live]
            staleness_m[v, ac] = r.apply_stale[v][live]
        return Timeline(
            arrival_time=self.arrival_time, client_id=self.client_id,
            cohort_id=self.cohort_id,
            round_of_origin=self.round_of_origin, staleness=self.staleness,
            commit_idx=self.commit_idx, start_mask=start_mask,
            apply_w=apply_w, staleness_m=staleness_m,
            commit_times=r.commit_times, durations=r.durations,
            quorum_wait=r.quorum_wait, applied=r.applied,
            quorum=self.quorum, discount=self.discount,
            tau_per_version=self.tau_per_version,
            started=r.started, crashed=r.crashed, lost=r.lost,
            corrupt=r.corrupt, dups=r.dups, retries=r.retries,
            timeouts=r.timeouts)


def compile_sparse_timeline(schedule, n_versions: int, *, quorum=0,
                            discount: float = 1.0, tau=1,
                            mask_rows: Optional[np.ndarray] = None,
                            k_max: Optional[int] = None,
                            capacity: Optional[int] = None,
                            faults=None, quorum_timeout: float = 0.0,
                            max_retries: int = 3) -> SparseTimeline:
    """Sparse counterpart of compile_timeline — same knobs (faults,
    quorum_timeout and per-version quorum arrays included), heap DES,
    (V, K) rows. k_max/capacity None = M (no truncation, no eviction:
    densify() reproduces the dense compiler exactly). Row widths are the
    realized maxima when k_max is None, else k_max."""
    R, M = schedule.delays.shape
    V = int(n_versions)
    taus = np.full(V, tau, np.int64) if np.ndim(tau) == 0 else \
        np.asarray(tau, np.int64)
    if taus.shape != (V,):
        raise ValueError(f"tau_per_version shape {taus.shape} != ({V},)")
    quorums = np.full(V, quorum, np.int64) if np.ndim(quorum) == 0 else \
        np.asarray(quorum, np.int64)
    if quorums.shape != (V,):
        raise ValueError(
            f"quorum_per_version shape {quorums.shape} != ({V},)")
    if mask_rows is not None:
        mask_rows = np.asarray(mask_rows, np.float32)
        if mask_rows.shape != (V, M):
            raise ValueError(
                f"mask_rows shape {mask_rows.shape} != ({V}, {M})")
    exact = k_max is None
    k = M if exact else int(k_max)
    cap = M if capacity is None else int(capacity)
    sim = _EventSim(M, _comm_of(schedule), schedule.t_server,
                    quorum=int(quorums[0]) if V else 0,
                    discount=discount, k_max=k, capacity=cap,
                    collect_events=True,
                    cohort_bounds=_cohort_bounds_of(schedule),
                    faults=_resolve_faults(schedule, faults),
                    quorum_timeout=quorum_timeout, max_retries=max_retries)
    steps = []
    with span("events.compile_sparse_timeline", versions=V, clients=M):
        for v in range(V):
            mask = mask_rows[v] if mask_rows is not None \
                else schedule.masks[v % R]
            steps.append(sim.step(schedule.delays[v % R], mask,
                                  int(taus[v]), quorum=int(quorums[v])))
    if exact:
        k_start = max([1] + [len(s.start_clients) for s in steps])
        k_apply = max([1] + [len(s.apply_clients) for s in steps])
    else:
        k_start = k_apply = k
    rows = _pack_rows(steps, k_start, k_apply, cap)
    ev = np.array(sim.finalize_events(), np.float64) \
        if sim.events else np.zeros((0, 5), np.float64)
    order = np.lexsort((ev[:, 1], ev[:, 0]))
    ev = ev[order]
    client_id = ev[:, 1].astype(np.int64)
    cohorts = (schedule.population.cohort_ids()
               if getattr(schedule, "population", None) is not None
               else np.zeros(M, np.int64))
    return SparseTimeline(
        rows=rows, arrival_time=ev[:, 0], client_id=client_id,
        cohort_id=cohorts[client_id],
        round_of_origin=ev[:, 2].astype(np.int64),
        staleness=ev[:, 3].astype(np.int64),
        commit_idx=ev[:, 4].astype(np.int64),
        quorum=int(quorums[0]) if V else
        (0 if np.ndim(quorum) else int(quorum)),
        discount=float(discount), tau_per_version=taus,
        n_clients=M, capacity=cap)


# ---------------------------------------------------------------------------
# the jit'd per-version step: fixed-shape record store + quorum commit
# ---------------------------------------------------------------------------

def init_store(sfl: SFLConfig) -> Dict[str, jax.Array]:
    """The in-flight contribution buffer, each slot the replayable
    seed-record wire format of a full MU-SplitFed contribution — (τ, P)
    server records, the client (key, coeff) pair, and the fetch-time loss
    metric. Zero coeffs make an empty/consumed slot replay-inert.

    Layout follows sfl.timeline: 'dense' keys slots by client id (M slots
    — a client computes at most one contribution at a time); 'sparse' is
    the bounded arrival-slot ring (resolve_store_geometry's capacity), the
    timeline stream owning the slot <-> contribution mapping."""
    M, T, P = sfl.n_clients, sfl.tau, sfl.n_perturbations
    lead = M
    if getattr(sfl, "timeline", "dense") == "sparse":
        lead = resolve_store_geometry(sfl)[1]
    return {
        "srv_keys": jnp.zeros((lead, T, P, 2), jnp.uint32),
        "srv_coeffs": jnp.zeros((lead, T, P), jnp.float32),
        "ukey": jnp.zeros((lead, 2), jnp.uint32),
        "ccoeff": jnp.zeros((lead,), jnp.float32),
        "loss0": jnp.zeros((lead,), jnp.float32),
    }


def resize_store(store: Dict[str, jax.Array], tau: int) -> Dict[str, jax.Array]:
    """Re-shape the record store's τ axis after a controller re-plans τ
    (the store is jit state, so its shapes are static per executable).
    Growth zero-pads (inert records); shrink truncates the tail server
    records of still-in-flight stale contributions — an approximation on
    work that would have been staleness-discounted anyway."""
    old = store["srv_keys"].shape[1]
    if tau == old:
        return store
    out = dict(store)
    if tau > old:
        pad = [(0, 0), (0, tau - old)] + [(0, 0)]
        out["srv_keys"] = jnp.pad(store["srv_keys"], pad + [(0, 0)])
        out["srv_coeffs"] = jnp.pad(store["srv_coeffs"], pad)
    else:
        out["srv_keys"] = store["srv_keys"][:, :tau]
        out["srv_coeffs"] = store["srv_coeffs"][:, :tau]
    return out


def async_mu_splitfed_step(cfg: ModelConfig, sfl: SFLConfig, params: Params,
                           store: Dict[str, jax.Array], batches,
                           start_mask: jax.Array, apply_w: jax.Array,
                           version_key, *, replay: str = "auto",
                           eval_loss: bool = True):
    """One server version of semi-async MU-SplitFed (pure/jit-able).

    start_mask (M,) selects the clients that fetch the CURRENT params and
    compute a fresh contribution this version (their records overwrite
    their store slot — the timeline guarantees the old slot was already
    committed). apply_w (M,) are the normalized staleness-discounted
    weights of this version's quorum commit: the whole store is replayed
    in one fused sweep with per-record coefficients c·η_g·w_m, so slots
    with w=0 (in-flight or idle) contribute exactly zero. Client compute
    happens at fetch time by construction, which is what makes stale
    records genuinely stale: they were generated against the params of
    their round_of_origin.
    """
    M = sfl.n_clients
    xc, xs = split_params(cfg, params, sfl.cut_units)
    mkeys = jax.vmap(lambda i: jax.random.fold_in(version_key, i))(
        jnp.arange(M))
    out = jax.vmap(lambda b, k: _client_round(cfg, sfl, xc, xs, b, k,
                                              eval_loss, replay)
                   )(batches, mkeys)
    fresh = {"srv_keys": out["srv_keys"], "srv_coeffs": out["srv_coeffs"],
             "ukey": out["ukey"], "ccoeff": out["ccoeff"],
             "loss0": out["loss0"]}

    def sel(new, old):
        m = start_mask.reshape((M,) + (1,) * (new.ndim - 1))
        return jnp.where(m > 0, new, old)

    store = jax.tree.map(sel, fresh, store)
    w = (sfl.lr_global * apply_w).astype(jnp.float32)
    xs_new = zo.replay_weighted_records(xs, store["srv_keys"],
                                        store["srv_coeffs"], w,
                                        sfl.perturbation_dist, impl=replay)
    xc_new = zo.replay_weighted_records(xc, store["ukey"], store["ccoeff"],
                                        w, sfl.perturbation_dist, impl=replay)
    metrics = {"loss": store["loss0"]}
    return merge_params(cfg, xc_new, xs_new), store, metrics


def async_mu_splitfed_sparse_step(cfg: ModelConfig, sfl: SFLConfig,
                                  params: Params,
                                  store: Dict[str, jax.Array], batches,
                                  start_client: jax.Array,
                                  start_slot: jax.Array,
                                  apply_slot: jax.Array,
                                  apply_w: jax.Array, version_key, *,
                                  replay: str = "auto",
                                  eval_loss: bool = True):
    """One server version over the arrival-slot ring store (pure/jit-able).

    The sparse twin of async_mu_splitfed_step: the device only ever sees
    the K rows a version touches. ``batches`` are PRE-GATHERED (K, ...)
    rows of the starting clients (the host stream gathered them — no
    (M, ...) batch is uploaded). start_client (K,) derives the per-client
    fold-in keys, so a starting client's records are bit-identical to the
    dense path's; start_slot (K,) scatters the fresh records into the ring
    (pad = capacity is dropped). apply_slot/apply_w (K,) gather this
    commit's records for one fused weighted replay — pads gather a real
    slot (clamped) but carry weight 0, which zeroes their coefficients, so
    they are replay-inert just like the dense path's w=0 rows.
    """
    xc, xs = split_params(cfg, params, sfl.cut_units)
    cid = jnp.clip(start_client, 0, sfl.n_clients - 1)
    mkeys = jax.vmap(lambda i: jax.random.fold_in(version_key, i))(cid)
    out = jax.vmap(lambda b, k: _client_round(cfg, sfl, xc, xs, b, k,
                                              eval_loss, replay)
                   )(batches, mkeys)
    fresh = {"srv_keys": out["srv_keys"], "srv_coeffs": out["srv_coeffs"],
             "ukey": out["ukey"], "ccoeff": out["ccoeff"],
             "loss0": out["loss0"]}
    store = {name: store[name].at[start_slot].set(val, mode="drop")
             for name, val in fresh.items()}
    w = (sfl.lr_global * apply_w).astype(jnp.float32)
    gather = lambda a: jnp.take(a, apply_slot, axis=0, mode="clip")
    xs_new = zo.replay_weighted_records(xs, gather(store["srv_keys"]),
                                        gather(store["srv_coeffs"]), w,
                                        sfl.perturbation_dist, impl=replay)
    xc_new = zo.replay_weighted_records(xc, gather(store["ukey"]),
                                        gather(store["ccoeff"]), w,
                                        sfl.perturbation_dist, impl=replay)
    metrics = {"loss": gather(store["loss0"])}
    return merge_params(cfg, xc_new, xs_new), store, metrics
