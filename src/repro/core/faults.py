"""Fault injection for the event-driven execution substrate.

A ``FaultPlan`` is the declarative description of everything that can go
wrong between a version broadcast and its commit:

  crash     a client crashes after fetching params (its contribution never
            materializes); it re-enters the idle pool only after an
            exponential-backoff re-dispatch delay (``backoff * 2**streak``
            simulated seconds, streak = consecutive crashes).
  loss      a delivery attempt is lost in transit; the client retransmits
            (one uplink ``t_comm`` per attempt) up to ``max_retries`` times
            before the contribution is dropped for good.
  dup       a delivery arrives twice; the duplicate is deduped by
            (client, round_of_origin) — one in-flight record per client is
            an invariant of the store, so the copy is counted and discarded.
  corrupt   the payload arrives with a bad coefficient checksum (see
            ``record_checksum``) and is dropped at delivery; the client is
            free to re-fetch at the next broadcast.
  kill      host-kill schedule: the train driver SIGKILLs itself when the
            run reaches this round — exercised by the crash-safe-checkpoint
            resume gate, never by the DES itself.

The plan is a frozen, hashable dataclass so it can live in ``SFLConfig``
(jit-static like the rest of the config). Every fault decision is a
counter-based SplitMix64 draw (``straggler._hash_uniform``) keyed on
(seed, lane, version, client) with lanes 4..7 — disjoint from the
schedule's participation/delay/Markov lanes 0..3 — so the dense compiler
and the sparse DES make bit-identical decisions, and a resumed or
re-planned stream replays the same faults (prefix stability).

The zero-fault contract: ``FaultPlan.none()`` (or ``faults=None``) must
leave the event stream byte-identical to an engine without this module —
callers gate every fault branch on ``plan.any()`` and consume no extra
randomness when it is False.

CLI grammar (``parse_faults``), population-style::

    faults:crash=0.2,loss=0.1,dup=0.05,corrupt=0.01,backoff=0.5,kill=6
    faults:crash=0.05,crash@slow=0.4        # per-cohort override by name
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.straggler import _hash_uniform

__all__ = ["FaultPlan", "ResolvedFaults", "parse_faults",
           "record_checksum", "OUT_DELIVER", "OUT_CRASH", "OUT_LOST",
           "OUT_CORRUPT"]

# keyed-draw lanes (straggler.py owns 0..3: participation, delays, Markov)
_LANE_CRASH = 4
_LANE_LOSS = 5
_LANE_DUP = 6
_LANE_CORRUPT = 7

# per-dispatch outcomes (ResolvedFaults.dispatch_fates)
OUT_DELIVER = 0     # arrives intact at `arrival` (after `retries` resends)
OUT_CRASH = 1       # crashed after fetch; idle again at `ready` (backoff)
OUT_LOST = 2        # every attempt lost; idle again at `ready`
OUT_CORRUPT = 3     # arrives at `arrival`, checksum fails, dropped there

# staleness codes for dropped contributions in the flat event view
# (>= 0: applied; -1: in flight at horizon / evicted — pre-existing)
STALE_CRASH = -2
STALE_LOST = -3
STALE_CORRUPT = -4

_FIELDS = ("crash", "loss", "dup", "corrupt")
_MAX_RETRY_STRIDE = 64          # loss draws key r = version*stride + attempt


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Hashable fault description (rates are per-dispatch probabilities)."""
    crash: float = 0.0
    loss: float = 0.0
    dup: float = 0.0
    corrupt: float = 0.0
    backoff: float = 0.5        # crash re-dispatch base delay (sim seconds)
    kill_round: int = -1        # host-kill schedule (-1 = never)
    # per-cohort rate overrides: (field, cohort_name, rate) triples
    overrides: Tuple[Tuple[str, str, float], ...] = ()

    def __post_init__(self):
        for f in _FIELDS:
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"faults: {f}={p} outside [0, 1]")
        if self.backoff < 0:
            raise ValueError(f"faults: backoff={self.backoff} < 0")
        for field, cohort, rate in self.overrides:
            if field not in _FIELDS:
                raise ValueError(
                    f"faults: unknown override field {field!r} "
                    f"(expected one of {_FIELDS})")
            if not 0.0 <= rate <= 1.0:
                raise ValueError(
                    f"faults: {field}@{cohort}={rate} outside [0, 1]")

    @classmethod
    def none(cls) -> "FaultPlan":
        return cls()

    def any(self) -> bool:
        """True when the DES must take fault branches at all. kill_round
        is driver-side (checkpoint exercise), not an event perturbation."""
        return any(getattr(self, f) > 0.0 for f in _FIELDS) or \
            any(rate > 0.0 for _, _, rate in self.overrides)

    def describe(self) -> str:
        parts = [f"{f}={getattr(self, f):g}" for f in _FIELDS
                 if getattr(self, f) > 0]
        parts += [f"{f}@{c}={r:g}" for f, c, r in self.overrides]
        if self.kill_round >= 0:
            parts.append(f"kill={self.kill_round}")
        return ",".join(parts) or "none"

    def resolve(self, n_clients: int, population=None,
                seed: int = 0) -> "ResolvedFaults":
        """Expand per-cohort overrides into (M,) per-client rate vectors.

        ``population`` is a ClientPopulation (or None for a scalar fleet);
        overrides name its cohorts. ``seed`` keys the fault draw lanes —
        callers pass the schedule seed so faults replay with the schedule.
        """
        M = int(n_clients)
        rates = {f: np.full(M, getattr(self, f), np.float64)
                 for f in _FIELDS}
        if self.overrides:
            if population is None:
                names = ", ".join(sorted({c for _, c, _ in self.overrides}))
                raise ValueError(
                    f"faults: cohort overrides ({names}) need a population")
            slices = {c.name: s for c, s in
                      zip(population.cohorts, population.slices())}
            for field, cohort, rate in self.overrides:
                if cohort not in slices:
                    raise ValueError(
                        f"faults: unknown cohort {cohort!r} "
                        f"(population has {sorted(slices)})")
                rates[field][slices[cohort]] = rate
        return ResolvedFaults(
            crash=rates["crash"], loss=rates["loss"], dup=rates["dup"],
            corrupt=rates["corrupt"], backoff=float(self.backoff),
            seed=int(seed))


class ResolvedFaults:
    """Per-client fault rates + the deterministic per-dispatch fate draw.

    Host-side only (the DES consumes this; nothing here may be referenced
    from a jit-traced body — the ``fault-isolation`` lint rule enforces
    that).
    """

    def __init__(self, *, crash: np.ndarray, loss: np.ndarray,
                 dup: np.ndarray, corrupt: np.ndarray, backoff: float,
                 seed: int):
        self.crash = crash
        self.loss = loss
        self.dup = dup
        self.corrupt = corrupt
        self.backoff = float(backoff)
        self.seed = int(seed)

    def dispatch_fates(self, v: int, ids: np.ndarray, t0: float,
                       delays: np.ndarray, comm: np.ndarray,
                       streaks: np.ndarray, max_retries: int
                       ) -> Dict[str, np.ndarray]:
        """The fate of each contribution dispatched at version ``v``.

        All arrays are over the dispatched ``ids`` (ascending client id).
        Deterministic: draws key on (seed, lane, version[, attempt],
        client), so both timeline backends and any replayed prefix agree.

          outcome   OUT_DELIVER / OUT_CRASH / OUT_LOST / OUT_CORRUPT
          arrival   delivery time for DELIVER/CORRUPT —
                    t0 + delay + (retries + 1) * comm (one uplink per
                    attempt, the retransmission model)
          ready     when a CRASH/LOST/CORRUPT client re-enters the idle
                    pool (crash: t0 + backoff * 2**streak; lost: the
                    moment the final attempt is known lost; corrupt: the
                    corrupted arrival itself)
          retries   retransmissions consumed (0 for a first-try delivery)
          dup       duplicated-delivery flag on delivered contributions
                    (deduped by construction — counted only)
        """
        if max_retries >= _MAX_RETRY_STRIDE:
            raise ValueError(
                f"max_retries={max_retries} >= {_MAX_RETRY_STRIDE}")
        ids = np.asarray(ids, np.int64)
        K = ids.size
        seed = self.seed
        crashed = _hash_uniform(seed, _LANE_CRASH, v, ids) < self.crash[ids]
        # first successful delivery attempt (a resend per lost attempt)
        attempt = np.zeros(K, np.int64)
        undelivered = np.ones(K, bool)
        for a in range(int(max_retries) + 1):
            lost_a = _hash_uniform(seed, _LANE_LOSS,
                                   v * _MAX_RETRY_STRIDE + a, ids) \
                < self.loss[ids]
            landed = undelivered & ~lost_a
            attempt[landed] = a
            undelivered &= lost_a
            if not undelivered.any():
                break
        all_lost = undelivered & ~crashed
        arrival = t0 + delays + (attempt + 1).astype(np.float64) * comm
        last_try = t0 + delays + float(max_retries + 1) * comm
        corrupt = (_hash_uniform(seed, _LANE_CORRUPT, v, ids)
                   < self.corrupt[ids]) & ~crashed & ~all_lost
        dup = (_hash_uniform(seed, _LANE_DUP, v, ids) < self.dup[ids]) \
            & ~crashed & ~all_lost
        outcome = np.full(K, OUT_DELIVER, np.int8)
        outcome[corrupt] = OUT_CORRUPT
        outcome[all_lost] = OUT_LOST
        outcome[crashed] = OUT_CRASH
        ready = np.zeros(K, np.float64)
        ready[crashed] = t0 + self.backoff * \
            np.power(2.0, streaks[ids][crashed].astype(np.float64))
        ready[all_lost] = last_try[all_lost]
        ready[corrupt] = arrival[corrupt]
        retries = np.where(all_lost, max_retries, attempt).astype(np.int64)
        retries[crashed] = 0
        return {"outcome": outcome, "arrival": arrival, "ready": ready,
                "retries": retries, "dup": dup}


def parse_faults(spec: str) -> FaultPlan:
    """Parse the ``faults:crash=p,loss=q,...`` CLI grammar.

    Items are comma-separated ``key=value`` pairs; rate keys (crash, loss,
    dup, corrupt) accept a per-cohort override ``key@cohort=value``;
    ``backoff`` is the crash re-dispatch base delay in simulated seconds
    and ``kill`` the host-kill round. The ``faults:`` prefix is optional.
    """
    body = spec[len("faults:"):] if spec.startswith("faults:") else spec
    kw: Dict[str, object] = {}
    overrides: List[Tuple[str, str, float]] = []
    for item in body.split(","):
        item = item.strip()
        if not item:
            continue
        try:
            key, val = item.split("=", 1)
        except ValueError:
            raise ValueError(
                f"bad faults item {item!r}: expected key=value "
                "(e.g. 'faults:crash=0.2,loss=0.1,backoff=0.5,kill=6')")
        key = key.strip()
        if "@" in key:
            field, cohort = key.split("@", 1)
            if field not in _FIELDS:
                raise ValueError(
                    f"bad faults item {item!r}: only {_FIELDS} take "
                    "@cohort overrides")
            overrides.append((field, cohort, float(val)))
        elif key in _FIELDS or key == "backoff":
            kw[key] = float(val)
        elif key == "kill":
            kw["kill_round"] = int(val)
        else:
            raise ValueError(
                f"bad faults item {item!r}: unknown key {key!r} "
                f"(expected one of {_FIELDS + ('backoff', 'kill')})")
    return FaultPlan(overrides=tuple(overrides), **kw)   # type: ignore[arg-type]


def record_checksum(*arrays) -> int:
    """Content checksum over seed-replay record arrays (keys + coeffs).

    The corruption detector of the wire format: a contribution's records
    are (key, coeff) pairs, so a CRC over their raw bytes is the cheapest
    end-to-end integrity check — computed host-side at payload boundaries
    (never inside a traced body). Also reused by the checkpoint layer for
    whole-bundle integrity.
    """
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc & 0xFFFFFFFF
