"""MU-SplitFed: the paper's unbalanced-update Split Federated round
(Algorithm 1), plus the M=1 MU-Split special case.

One global round t:
  Phase 1 (per client m, in parallel):
    client:  u_m ~ key; send H_m = {h, h+, h-}  (three client forwards)
    server:  τ local ZO steps on the *stale* unperturbed h (Eq. 5) —
             x_{s,m}^{t,i+1} = x_{s,m}^{t,i} − η_s (δ_i/2λ) u_i
    server:  δ_c,m = F(x_{s,m}^{t,τ}, h+) − F(x_{s,m}^{t,τ}, h−)   (Eq. 6)
             → one scalar back to the client
    client:  x_{c,m}^{t+1} = x_c^t − η_c (δ_c,m/2λ) u_m
  Phase 2:  dual aggregation (Eq. 7) with global lr η_g.

Execution modes (planner-chosen; both lower the same math):
  client_mode='parallel'    vmap over M — per-client server replicas stacked
                            (M, …), M mapped to the mesh 'data' axis.
  client_mode='sequential'  lax.scan over M — one working server copy
                            (FSDP'd over the whole mesh); for archs whose
                            M replicas cannot fit HBM.
Aggregation modes:
  'dense'        Eq. 7 literally — param-sized mean over M (all-reduce).
  'seed_replay'  beyond-paper: replay the (key, δ)-records of every client
                 directly into the global params — only O(Mτ P) scalars
                 cross the aggregation axis (paper Appendix A realized as a
                 collective-compression scheme). The records are applied
                 through zo.fused_replay_updates: with dist='counter' all
                 N = Mτ P contributions are accumulated in one parameter
                 sweep (ladder v4) instead of an N-step scan (``replay``
                 selects the path; 'scan' keeps the v3 behaviour).

The round function is pure/jit-able; straggler wall-clock simulation and
participation decisions live outside (core/straggler.py) and enter here only
through ``active_mask``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import zo
from repro.models import client_forward, merge_params, server_forward, split_params

Params = Any


class RoundMetrics(NamedTuple):
    loss: jax.Array          # (M,) round-start loss per client (f32)
    server_deltas: jax.Array  # (M, tau) mean SPSA deltas on the server
    client_delta: jax.Array  # (M,) scalar ZO-backprop differences


# ---------------------------------------------------------------------------
# per-client phases
# ---------------------------------------------------------------------------

def _client_messages(cfg: ModelConfig, sfl: SFLConfig, xc: Params, batch,
                     ukey):
    """Three client forwards -> (h, h+, h-). The perturbation u_m never
    leaves the client; only its key is kept for the later update."""
    h = client_forward(cfg, xc, batch)
    hp = client_forward(cfg, zo.perturb(xc, ukey, +sfl.zo_eps,
                                        sfl.perturbation_dist), batch)
    hm = client_forward(cfg, zo.perturb(xc, ukey, -sfl.zo_eps,
                                        sfl.perturbation_dist), batch)
    return h, hp, hm


def _server_tau_steps(cfg: ModelConfig, sfl: SFLConfig, xs: Params, h, batch,
                      skey, replay: str = "auto"):
    """τ unbalanced ZO steps on the stale embedding h. Returns
    (xs_final, deltas (τ,), records (keys (τ,P), coeffs (τ,P)))."""
    def loss_of(sp):
        return server_forward(cfg, sp, h, batch)

    def step(sp, i):
        k_i = jax.random.fold_in(skey, i)
        sp, mean_delta, (pkeys, coeffs) = zo.spsa_step(
            loss_of, sp, k_i, sfl.zo_eps, sfl.lr_server,
            sfl.n_perturbations, sfl.perturbation_dist, replay=replay)
        return sp, (mean_delta, pkeys, coeffs)

    xs_f, (deltas, keys, coeffs) = jax.lax.scan(step, xs,
                                                jnp.arange(sfl.tau))
    return xs_f, deltas, (keys, coeffs)


def _client_round(cfg: ModelConfig, sfl: SFLConfig, xc: Params, xs: Params,
                  batch, mkey, eval_loss: bool = True,
                  replay: str = "auto"):
    """Full per-client round. Returns per-client results."""
    ukey = jax.random.fold_in(mkey, 0)
    skey = jax.random.fold_in(mkey, 1)
    h, hp, hm = _client_messages(cfg, sfl, xc, batch, ukey)
    loss0 = (server_forward(cfg, xs, h, batch) if eval_loss
             else jnp.zeros((), jnp.float32))          # round-start metric
    xs_f, deltas, records = _server_tau_steps(cfg, sfl, xs, h, batch, skey,
                                              replay)
    # ZO backprop (Eq. 6): scalar from the *final* server model
    delta_c = (server_forward(cfg, xs_f, hp, batch)
               - server_forward(cfg, xs_f, hm, batch)).astype(jnp.float32)
    # client update coeff: η_c · δ_c / (2λ); u replayed from ukey
    ccoeff = sfl.lr_client * delta_c / (2.0 * sfl.zo_eps)
    return {
        "xs_final": xs_f,
        "deltas": deltas,
        "srv_keys": records[0], "srv_coeffs": records[1],
        "ukey": ukey, "ccoeff": ccoeff,
        "loss0": loss0,
    }


# ---------------------------------------------------------------------------
# the global round
# ---------------------------------------------------------------------------

def mu_splitfed_round(cfg: ModelConfig, sfl: SFLConfig, params: Params,
                      batches, active_mask, round_key, *,
                      client_mode: str = "parallel",
                      aggregation: str = "dense",
                      replay: str = "auto",
                      eval_loss: bool = True
                      ) -> Tuple[Params, RoundMetrics]:
    """One global round. ``batches`` leaves have leading M dim;
    ``active_mask`` is (M,) f32 participation weights (0 = straggler dropped /
    not sampled). ``replay`` ('auto'|'fused'|'scan') selects how replayable
    records are applied — see zo.fused_replay_updates. Returns
    (new_params, metrics)."""
    M = sfl.n_clients
    xc, xs = split_params(cfg, params, sfl.cut_units)
    mkeys = jax.vmap(lambda i: jax.random.fold_in(round_key, i))(jnp.arange(M))
    wsum = jnp.maximum(jnp.sum(active_mask), 1.0)
    w = (active_mask / wsum).astype(jnp.float32)        # (M,) aggregation wts

    if client_mode == "parallel":
        out = jax.vmap(lambda b, k: _client_round(cfg, sfl, xc, xs, b, k,
                                                  eval_loss, replay)
                       )(batches, mkeys)
        if aggregation == "dense":
            # Eq. 7: x_s' = x_s + η_g Σ w_m (x_{s,m}^τ − x_s)
            def agg(g, stacked):
                delta = jnp.tensordot(w, (stacked - g[None]).astype(jnp.float32),
                                      axes=1)
                return (g + sfl.lr_global * delta).astype(g.dtype)
            xs_new = jax.tree.map(agg, xs, out["xs_final"])
        else:  # seed_replay: flatten (M, τ, P) records, weight by η_g·w_m
            xs_new = zo.replay_weighted_records(
                xs, out["srv_keys"], out["srv_coeffs"], sfl.lr_global * w,
                sfl.perturbation_dist, impl=replay)
    elif client_mode == "sequential":
        def body(carry, xs_in):
            acc = carry
            b, k, wm = xs_in
            r = _client_round(cfg, sfl, xc, xs, b, k, eval_loss, replay)
            if aggregation == "dense":
                acc = jax.tree.map(
                    lambda a, f, g: a + wm * (f - g).astype(jnp.float32),
                    acc, r["xs_final"], xs)
            light = {k2: r[k2] for k2 in
                     ("deltas", "srv_keys", "srv_coeffs", "ukey", "ccoeff",
                      "loss0")}
            return acc, light
        acc0 = (jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), xs)
                if aggregation == "dense" else jnp.zeros(()))
        acc, out = jax.lax.scan(body, acc0, (batches, mkeys, w))
        if aggregation == "dense":
            xs_new = jax.tree.map(
                lambda g, a: (g + sfl.lr_global * a).astype(g.dtype), xs, acc)
        else:
            xs_new = zo.replay_weighted_records(
                xs, out["srv_keys"], out["srv_coeffs"], sfl.lr_global * w,
                sfl.perturbation_dist, impl=replay)
    else:
        raise ValueError(client_mode)

    # client aggregation — always replayable (Eq. 7 left): the per-client
    # update is rank-one in u_m, so Σ_m w_m Δ_m is Σ of replayed records.
    xc_new = zo.replay_weighted_records(
        xc, out["ukey"], out["ccoeff"], sfl.lr_global * w,
        sfl.perturbation_dist, impl=replay)

    metrics = RoundMetrics(loss=out["loss0"], server_deltas=out["deltas"],
                           client_delta=out["ccoeff"])
    return merge_params(cfg, xc_new, xs_new), metrics


def mu_split_round(cfg: ModelConfig, sfl: SFLConfig, params: Params, batch,
                   round_key) -> Tuple[Params, RoundMetrics]:
    """MU-Split: the single-client (M=1, SL) special case of Sec. 4.1."""
    sfl1 = (sfl if sfl.n_clients == 1
            else dataclasses.replace(sfl, n_clients=1))
    batches = jax.tree.map(lambda a: a[None], batch)
    return mu_splitfed_round(cfg, sfl1, params, batches,
                             jnp.ones((1,), jnp.float32), round_key)
