"""First-class client populations: heterogeneous cohorts as config.

The paper simulates a *homogeneous* fleet — one delay distribution, one
participation fraction — but the SFL literature studies device-tiered
cohorts (HASFL, arXiv:2506.08426) and unstable/correlated participation
(arXiv:2509.17398). This module makes the client fleet an explicit,
hashable spec:

  Cohort             one named device tier: size, delay model, comm scale,
                     participation fraction, and an availability process
                     ('iid' per-round draws, or a 'markov' up/down chain
                     for bursty correlated dropouts).
  ClientPopulation   a tuple of cohorts composing into per-client (M,)
                     system vectors; `straggler.make_schedule` samples
                     delays / participation / availability per cohort.
  parse_population   the CLI grammar ("tiered:4x1.0,12x0.2").

Everything is a frozen dataclass of literals, so a population can sit
inside SFLConfig (which jit treats as a static arg) and hash/compare like
any other config. The legacy scalar knobs (`straggler_rate`,
`participation`) remain as a deprecated single-cohort shorthand resolved
through `ClientPopulation.resolve(sfl)`; a single-iid-cohort population
reproduces the historical schedule RNG draws bit-for-bit
(tests/test_population.py pins this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["DelayModel", "Cohort", "ClientPopulation", "parse_population",
           "AvailRow"]


class AvailRow:
    """One version's availability, bucketed by cohort — the streaming mask
    protocol between schedule samplers and the sparse DES.

    Instead of an (M,) dense 0/1 row, availability is one tagged record per
    cohort (cohorts are contiguous client-id ranges):

      ('all',)             every client in the cohort is available
      ('none',)            tier down / nobody drawn
      ('ids', ids)         exactly ``ids`` (sorted GLOBAL client ids)
      ('not_ids', ids)     everyone EXCEPT ``ids`` (sorted down-set) — the
                           natural shape of a mostly-up Markov chain

    The DES's cohort idle index consumes this directly, so a version's
    candidate selection costs O(K·log M) plus the size of the sparse
    records — never an O(M) scan — and a million-client schedule is never
    densified. ``from_mask`` adapts a dense row (the bit-exact reference
    path); ``densify`` expands back for tests.
    """

    __slots__ = ("bounds", "kinds", "ids", "sets")

    def __init__(self, bounds, kinds, ids):
        self.bounds = bounds            # [(lo, hi)] per cohort
        self.kinds = kinds              # ['all'|'none'|'ids'|'not_ids']
        self.ids = ids                  # sorted global-id arrays or None
        # O(1) membership for 'not_ids' admission checks, built lazily
        self.sets = [None] * len(kinds)

    def down_set(self, c: int):
        if self.sets[c] is None:
            self.sets[c] = frozenset(self.ids[c].tolist())
        return self.sets[c]

    @classmethod
    def from_mask(cls, mask: np.ndarray, bounds) -> "AvailRow":
        """Bucket a dense (M,) 0/1 row by cohort (O(M) — the adapter for
        dense-schedule-driven paths, which already hold the row)."""
        mask = np.asarray(mask)
        kinds, ids = [], []
        for lo, hi in bounds:
            nz = np.flatnonzero(mask[lo:hi] > 0)
            if nz.size == hi - lo:
                kinds.append("all")
                ids.append(None)
            elif nz.size == 0:
                kinds.append("none")
                ids.append(None)
            else:
                kinds.append("ids")
                ids.append(nz.astype(np.int64) + lo)
        return cls(list(bounds), kinds, ids)

    def densify(self, n_clients: int) -> np.ndarray:
        row = np.zeros(n_clients, np.float32)
        for c, (lo, hi) in enumerate(self.bounds):
            k = self.kinds[c]
            if k == "all":
                row[lo:hi] = 1.0
            elif k == "ids":
                row[self.ids[c]] = 1.0
            elif k == "not_ids":
                row[lo:hi] = 1.0
                row[self.ids[c]] = 0.0
        return row


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Per-round client compute times (seconds, simulated).

    t_m = base * (1 + Exp(scale))  — heterogeneous, heavy-tailed (paper §5
    follows [8,12] and samples from an exponential distribution).
    ``hetero`` optionally fixes a per-client speed multiplier (systematic
    stragglers rather than purely stochastic ones).
    """
    base: float = 1.0
    scale: float = 1.0
    hetero: Optional[Tuple[float, ...]] = None

    @property
    def stochastic(self) -> bool:
        return self.scale > 0 or self.hetero is not None

    def sample(self, rng: np.random.Generator, n_clients: int,
               n_rounds: int) -> np.ndarray:
        t = self.base * (1.0 + rng.exponential(self.scale,
                                               size=(n_rounds, n_clients)))
        if self.hetero is not None:
            t = t * np.asarray(self.hetero)[None, :]
        return t


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One device tier of the fleet.

    availability='iid'    : each round draws an independent participation
                            mask (fraction ``participation``, always >=1
                            active in the cohort — the legacy behaviour).
    availability='markov' : each client carries an up/down state; per round
                            an up client drops with ``p_dropout`` and a
                            down client recovers with ``p_recover`` (bursty,
                            temporally correlated dropouts). A
                            ``participation`` fraction < 1 is drawn on top
                            of the chain.
    availability='markov-shared' : ONE up/down chain for the whole cohort —
                            every client drops and recovers together
                            (tier-wide outages: a rack, a carrier, a
                            region). One uniform draw per round per cohort;
                            ``participation`` < 1 still draws per client on
                            top while the tier is up.
    ``t_comm_scale`` scales the schedule's per-round t_comm for this tier
    (slow uplinks); the round is bounded by the slowest *active* link.
    """
    name: str
    n: int
    delay: DelayModel = DelayModel(base=1.0, scale=0.0)
    participation: float = 1.0
    availability: str = "iid"
    p_dropout: float = 0.0
    p_recover: float = 0.5
    t_comm_scale: float = 1.0

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"cohort {self.name!r}: n must be >= 1")
        if self.availability not in ("iid", "markov", "markov-shared"):
            raise ValueError(f"cohort {self.name!r}: availability must be "
                             f"'iid'|'markov'|'markov-shared', "
                             f"got {self.availability!r}")
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(f"cohort {self.name!r}: participation must be "
                             f"in (0, 1], got {self.participation}")


@dataclasses.dataclass(frozen=True)
class ClientPopulation:
    """The whole client fleet as an ordered tuple of cohorts.

    Client index space is the concatenation of the cohorts in order:
    cohort 0 owns clients [0, n0), cohort 1 owns [n0, n0+n1), ...
    """
    cohorts: Tuple[Cohort, ...]

    def __post_init__(self):
        if not self.cohorts:
            raise ValueError("population needs at least one cohort")
        names = [c.name for c in self.cohorts]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cohort names: {names}")

    # -- composition into per-client (M,) vectors ---------------------------

    @property
    def n_clients(self) -> int:
        return sum(c.n for c in self.cohorts)

    def slices(self) -> List[slice]:
        """Per-cohort client-index slices, in cohort order."""
        out, i = [], 0
        for c in self.cohorts:
            out.append(slice(i, i + c.n))
            i += c.n
        return out

    def cohort_ids(self) -> np.ndarray:
        """(M,) int array: which cohort each client belongs to."""
        return np.concatenate([np.full(c.n, i, np.int64)
                               for i, c in enumerate(self.cohorts)])

    def t_comm_scales(self) -> np.ndarray:
        """(M,) per-client communication-time multipliers."""
        return np.concatenate([np.full(c.n, c.t_comm_scale, np.float64)
                               for c in self.cohorts])

    @property
    def uniform_comm(self) -> bool:
        return all(c.t_comm_scale == 1.0 for c in self.cohorts)

    def client_vectors(self) -> Dict[str, np.ndarray]:
        """The fleet's per-client (M,) system vectors, expanded from the
        cohort spec — everything about population state that scales with
        M. This is the sharding surface: sharding/specs.population_pspecs
        lays these out over the mesh 'data' axis (the ring store's slot
        dim rides the same axis), so fleet vectors never have to fit one
        host/device past small M."""
        def expand(field, dtype):
            return np.concatenate([np.full(c.n, field(c), dtype)
                                   for c in self.cohorts])
        return {
            "cohort_id": self.cohort_ids(),
            "t_comm_scale": self.t_comm_scales(),
            "delay_base": expand(lambda c: c.delay.base, np.float64),
            "delay_scale": expand(lambda c: c.delay.scale, np.float64),
            "participation": expand(lambda c: c.participation, np.float64),
        }

    def sampler(self) -> "PopulationSampler":
        return PopulationSampler(self)

    def describe(self) -> str:
        return " + ".join(
            f"{c.name}[n={c.n}, base={c.delay.base:g}, "
            f"scale={c.delay.scale:g}, part={c.participation:g}, "
            f"{c.availability}"
            + (f"(drop={c.p_dropout:g}/rec={c.p_recover:g})"
               if c.availability.startswith("markov") else "")
            + (f", comm×{c.t_comm_scale:g}" if c.t_comm_scale != 1.0 else "")
            + "]" for c in self.cohorts)

    # -- legacy shorthand ---------------------------------------------------

    @classmethod
    def single(cls, n_clients: int, *, delay: Optional[DelayModel] = None,
               straggler_scale: float = 0.0,
               participation: float = 1.0) -> "ClientPopulation":
        """One homogeneous iid cohort — the legacy scalar-knob fleet."""
        return cls(cohorts=(Cohort(
            name="all", n=n_clients,
            delay=delay or DelayModel(base=1.0, scale=straggler_scale),
            participation=participation),))

    @classmethod
    def resolve(cls, sfl) -> "ClientPopulation":
        """The one resolution path from an SFLConfig: an explicit
        ``sfl.population`` wins; otherwise the deprecated scalar knobs
        (``straggler_rate``, ``participation``) become a single cohort."""
        pop = getattr(sfl, "population", None)
        if pop is not None:
            if pop.n_clients != sfl.n_clients:
                raise ValueError(
                    f"population has {pop.n_clients} clients but "
                    f"sfl.n_clients={sfl.n_clients}")
            return pop
        return cls.single(sfl.n_clients, straggler_scale=sfl.straggler_rate,
                          participation=sfl.participation)


class PopulationSampler:
    """Stateful per-round sampler (host-side, numpy RNG).

    Draw order per round is pinned to the historical scalar path — for each
    cohort in order: the delay draw (only when that cohort's delay model is
    stochastic), then for each cohort in order: the availability /
    participation draw — so a single-iid-cohort population consumes the RNG
    stream exactly like the legacy ``make_schedule`` loop and reproduces its
    arrays bit-for-bit. Markov chains start all-up and take one transition
    step before round 0 is read.
    """

    def __init__(self, population: ClientPopulation):
        self.pop = population
        self._slices = population.slices()
        self._up = [np.ones(c.n, bool) for c in population.cohorts]

    def delays_row(self, rng: np.random.Generator) -> np.ndarray:
        row = np.empty(self.pop.n_clients, np.float64)
        for c, sl in zip(self.pop.cohorts, self._slices):
            row[sl] = (c.delay.sample(rng, c.n, 1)[0] if c.delay.stochastic
                       else np.full(c.n, c.delay.base))
        return row

    def participation_row(self, rng: np.random.Generator) -> np.ndarray:
        from repro.core.straggler import participation_mask
        row = np.empty(self.pop.n_clients, np.float32)
        for i, (c, sl) in enumerate(zip(self.pop.cohorts, self._slices)):
            if c.availability == "markov":
                u = rng.random(c.n)
                self._up[i] = np.where(self._up[i], u >= c.p_dropout,
                                       u < c.p_recover)
                m = self._up[i].astype(np.float32)
                if c.participation < 1.0:
                    m = m * participation_mask(rng, c.n, c.participation)
            elif c.availability == "markov-shared":
                # one transition draw for the whole tier: correlated,
                # rack/carrier-level outages — every client flips together
                u = rng.random()
                up = bool(self._up[i][0])
                up = (u >= c.p_dropout) if up else (u < c.p_recover)
                self._up[i][:] = up
                m = np.full(c.n, float(up), np.float32)
                if up and c.participation < 1.0:
                    m = m * participation_mask(rng, c.n, c.participation)
            else:
                m = participation_mask(rng, c.n, c.participation)
            row[sl] = m
        return row


# ---------------------------------------------------------------------------
# CLI grammar
# ---------------------------------------------------------------------------

def parse_population(spec: str, *,
                     straggler_scale: float = 0.0) -> ClientPopulation:
    """Parse the ``--population`` CLI grammar into a ClientPopulation.

        tiered:<n>x<speed>[@<part>][~<p_drop>/<p_recover>][%<comm_scale>],...

    Each comma-separated item is one cohort of ``n`` clients running at
    relative ``speed`` (delay base = 1/speed, so speed 0.2 is 5× slower
    than speed 1.0). Optional suffixes: ``@0.5`` participation fraction,
    ``~0.05/0.2`` per-client Markov availability (P(up→down)/P(down→up)),
    ``~~0.05/0.2`` a SHARED per-cohort chain (the whole tier drops and
    recovers together — correlated outages), ``%4`` communication-time
    scale. ``straggler_scale`` is the shared exponential jitter applied to
    every cohort (the CLI's --straggler-scale).

    Examples:
        tiered:4x1.0,12x0.2            4 fast + 12 five-times-slower clients
        tiered:4x1.0,4x0.25~0.05/0.2   slow tier with bursty Markov dropouts
        tiered:4x1.0,4x0.25~~0.05/0.2  slow tier with tier-WIDE outages
    """
    body = spec.split(":", 1)[1] if spec.startswith("tiered:") else spec
    cohorts = []
    for i, item in enumerate(x for x in body.split(",") if x.strip()):
        item = item.strip()
        comm_scale = 1.0
        if "%" in item:
            item, tail = item.rsplit("%", 1)
            comm_scale = float(tail)
        availability, p_drop, p_rec = "iid", 0.0, 0.5
        if "~" in item:
            item, tail = item.rsplit("~", 1)
            availability = "markov"
            if item.endswith("~"):          # `~~p/p`: shared cohort chain
                item = item[:-1]
                availability = "markov-shared"
            p_drop, p_rec = (float(x) for x in tail.split("/"))
        part = 1.0
        if "@" in item:
            item, tail = item.rsplit("@", 1)
            part = float(tail)
        try:
            n_str, speed_str = item.split("x", 1)
            n, speed = int(n_str), float(speed_str)
        except ValueError:
            raise ValueError(
                f"bad cohort spec {item!r} in {spec!r}; expected "
                "<n>x<speed>[@part][~p_drop/p_recover][%comm_scale]")
        if speed <= 0:
            raise ValueError(f"cohort speed must be > 0, got {speed}")
        cohorts.append(Cohort(
            name=f"tier{i}", n=n,
            delay=DelayModel(base=1.0 / speed, scale=straggler_scale),
            participation=part, availability=availability,
            p_dropout=p_drop, p_recover=p_rec, t_comm_scale=comm_scale))
    return ClientPopulation(cohorts=tuple(cohorts))
