"""Paper core: MU-SplitFed (unbalanced-update split federated learning with
zeroth-order optimization), its baselines, the straggler system model, and
the convergence-theory calculators."""
from repro.core import baselines, straggler, theory, zo
from repro.core.splitfed import (RoundMetrics, mu_split_round,
                                 mu_splitfed_round)
