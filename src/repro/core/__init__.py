"""Paper core: MU-SplitFed (unbalanced-update split federated learning with
zeroth-order optimization), its baselines, the straggler system model, the
convergence-theory calculators, and the unified algorithm engine that runs
any of them as a chunked on-device multi-round scan."""
from repro.core import baselines, engine, population, straggler, theory, zo
from repro.core.engine import (ALGORITHMS, AdaptiveTau, Algorithm, ChunkInfo,
                               Controller, EngineResult, SchedWindow,
                               apply_resume_overrides, get_algorithm,
                               restore_run, run_rounds)
from repro.core.population import (ClientPopulation, Cohort, DelayModel,
                                   parse_population)
from repro.core.splitfed import (RoundMetrics, mu_split_round,
                                 mu_splitfed_round)
from repro.core.straggler import Schedule, make_schedule
