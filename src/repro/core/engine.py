"""Unified algorithm engine: one driver, every algorithm, rounds fused
on-device.

The paper's headline claim is wall-clock (rounds over *time*), yet the
historical drivers executed rounds one Python iteration at a time — each
paying a dispatch, a host sync, and an un-donated parameter copy per round,
and each hand-rolling its own loop + algorithm special cases. This module
replaces all of them:

  Algorithm    protocol (init_state / round_fn / time_model / metrics_spec)
               with registered adapters for mu_splitfed, vanilla, gas,
               fedavg, and fedlora — every algorithm is a pure
               (params, state, batch, mask, key) -> (params, state, metrics)
               round, so the driver is algorithm-agnostic (GAS state
               threading included).
  run_rounds   the driver. mode='scan' (default) lifts the loop into a
               chunked, jit'd jax.lax.scan over rounds with params/state
               DONATED across chunks: straggler delays, participation /
               deadline masks (straggler.make_schedule) and per-round
               fold-in keys are precomputed on host as stacked (R, M) /
               (R, 2) arrays and scanned as data; metrics are stacked per
               chunk and flushed to host only at chunk boundaries — which
               is also where checkpointing hooks in. mode='python' keeps
               the legacy one-jit-call-per-round loop as the equivalence
               baseline (benchmarks/bench_rounds.py gates scan == python
               on the loss trajectory; perf ladder rung v5). mode='async'
               scans the compiled event timeline instead (core/events.py):
               quorum-committed server versions, the in-flight seed-record
               buffer carried as engine state, staleness-discounted fused
               replay — rung v6, gated async == scan at full quorum.
  Controller   chunk-boundary policy hook: ``update(round_idx, window,
               metrics) -> {sfl field: value}``. AdaptiveTau is the
               paper's "adaptive tuning of τ" — it re-plans τ from the
               observed straggler gap via straggler.plan_tau; a τ change
               re-jits the round body, amortized across chunks by the
               per-algo executable cache.

Chunk boundaries are aligned to ckpt_every, so a run killed after chunk k
resumes from its checkpoint onto the *same* round boundaries — with
stateless data order and precomputed schedules the resumed trajectory is
bit-identical to an uninterrupted run (tests/test_engine.py). Stateful
algorithms (GAS activation buffer, FedLoRA adapters) checkpoint their
engine state alongside params as a {'params','state'} bundle; restore_run
resumes them exactly. Controller runs additionally record the overrides in
effect and the controller's own state in the checkpoint metadata —
apply_resume_overrides replays them, so a resumed adaptive-τ run continues
at the adapted τ/η_s with its EMA intact (the first post-resume chunk has
no observed window and keeps the restored τ, so such runs are exact up to
that one skipped re-plan).
"""
from __future__ import annotations

import dataclasses
from time import perf_counter
from typing import (Any, Callable, Dict, List, NamedTuple, Optional, Protocol,
                    Tuple, Union, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import events
from repro.core import straggler as strag
from repro.obs.telemetry import RoundTelemetry, TelemetrySink
from repro.obs.trace import span
from repro.core.baselines import (fedavg_round, fedlora_round, gas_init_state,
                                  gas_round, vanilla_splitfed_round)
from repro.core.splitfed import mu_splitfed_round

Params = Any
State = Any
Batch = Dict[str, Any]
MetricsDict = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# the Algorithm protocol + registry
# ---------------------------------------------------------------------------

@runtime_checkable
class Algorithm(Protocol):
    """One federated algorithm as the engine sees it.

    round_fn must be pure/jit-able; all system effects (delays, staleness,
    participation) enter as the (M,) mask data row. State is an arbitrary
    pytree carried across rounds (empty tuple for stateless algorithms).
    """
    name: str

    def init_state(self, cfg: ModelConfig, sfl: SFLConfig, params: Params,
                   batch0: Batch) -> State: ...

    def round_fn(self, cfg: ModelConfig, sfl: SFLConfig, params: Params,
                 state: State, batch: Batch, mask: jax.Array, key: jax.Array
                 ) -> Tuple[Params, State, MetricsDict]: ...

    def time_model(self, delays: np.ndarray, mask: np.ndarray,
                   sfl: SFLConfig, sched: strag.Schedule) -> float: ...

    def metrics_spec(self, cfg: ModelConfig, sfl: SFLConfig
                     ) -> Dict[str, Tuple[int, ...]]: ...


ALGORITHMS: Dict[str, Callable[..., Algorithm]] = {}
_INSTANCES: Dict[Tuple[str, Tuple], Algorithm] = {}


def register(cls):
    ALGORITHMS[cls.name] = cls
    # a re-registration must not leave get_algorithm serving memoized
    # instances of the previous class under the same name
    for k in [k for k in _INSTANCES if k[0] == cls.name]:
        del _INSTANCES[k]
    return cls


def get_algorithm(name: Union[str, Algorithm], **opts) -> Algorithm:
    """Resolve an algorithm by registry name or pass a ready-made Algorithm
    instance through.

    By-name resolution is MEMOIZED on (name, opts): repeated calls return
    the same adapter instance, so the engine's per-instance jit cache
    (keyed on mode/cfg/sfl) survives across run_rounds calls — a benchmark
    sweep re-running the same configuration hits the compiled executables
    instead of re-tracing a fresh adapter every run
    (tests/test_engine.py counts the traces)."""
    if isinstance(name, str):
        if name not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {name!r}; "
                             f"registered: {sorted(ALGORITHMS)}")
        k = (name, tuple(sorted(opts.items())))
        try:
            hash(k)
        except TypeError:               # unhashable opt values: no memo
            return ALGORITHMS[name](**opts)
        if k not in _INSTANCES:
            _INSTANCES[k] = ALGORITHMS[name](**opts)
        return _INSTANCES[k]
    if opts:
        raise ValueError("opts only apply when resolving by name")
    return name


def clear_algorithm_cache() -> None:
    """Drop all memoized adapter instances (and with them their per-instance
    compiled-executable caches). Long-lived processes sweeping many distinct
    (cfg, sfl) configurations can call this between sweeps to release the
    retained executables."""
    _INSTANCES.clear()


class AlgorithmBase:
    """Shared defaults: stateless, standard mask row, per-client loss."""

    def init_state(self, cfg, sfl, params, batch0) -> State:
        return ()

    def round_mask(self, sched: strag.Schedule, r: int) -> np.ndarray:
        """The (M,) mask row round r's round_fn consumes (GAS overrides
        with its freshness rule)."""
        return sched.masks[r % sched.n_rounds]

    def metrics_spec(self, cfg, sfl) -> Dict[str, Tuple[int, ...]]:
        return {"loss": (sfl.n_clients,)}


@register
class MuSplitFed(AlgorithmBase):
    """The paper's τ-unbalanced split federated round (Algorithm 1)."""
    name = "mu_splitfed"

    def __init__(self, client_mode: str = "parallel",
                 aggregation: str = "dense", replay: str = "auto",
                 eval_loss: bool = True):
        self.client_mode = client_mode
        self.aggregation = aggregation
        self.replay = replay
        self.eval_loss = eval_loss

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, m = mu_splitfed_round(
            cfg, sfl, params, batch, mask, key, client_mode=self.client_mode,
            aggregation=self.aggregation, replay=self.replay,
            eval_loss=self.eval_loss)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_mu_splitfed(delays, mask, sched.t_server,
                                            sfl.tau, sched.comm_for(mask))

    def metrics_spec(self, cfg, sfl):
        M = sfl.n_clients
        return {"loss": (M,), "server_deltas": (M, sfl.tau),
                "client_delta": (M,)}


@register
class VanillaSplitFed(MuSplitFed):
    """SplitFed without unbalanced updates — exactly MU-SplitFed at τ=1."""
    name = "vanilla"

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, m = vanilla_splitfed_round(
            cfg, sfl, params, batch, mask, key, client_mode=self.client_mode,
            aggregation=self.aggregation, replay=self.replay,
            eval_loss=self.eval_loss)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_vanilla(delays, mask, sched.t_server,
                                        sched.comm_for(mask))

    def metrics_spec(self, cfg, sfl):
        return {"loss": (sfl.n_clients,), "server_deltas": (sfl.n_clients, 1),
                "client_delta": (sfl.n_clients,)}


@register
class AsyncMuSplitFed(MuSplitFed):
    """Semi-async MU-SplitFed over the compiled event timeline
    (core/events.py): the server commits a version as soon as a quorum of
    contributions has arrived; late arrivals fold into a later commit
    with a staleness discount, applied through the fused seed-replay path.
    Run it with ``mode='async'`` — the quorum / discount knobs live in
    SFLConfig (``quorum``, ``staleness_discount``). Under the sync modes
    ('scan'/'python') it degenerates to MU-SplitFed with seed-replay
    aggregation (its record store rides along untouched). Seed replay is
    not optional here: the in-flight buffer IS the (key, coeff) wire
    format — dense aggregation would mean buffering param-sized server
    trees per client — so anything but aggregation='seed_replay' is
    rejected rather than silently ignored."""
    name = "async_mu_splitfed"

    def __init__(self, client_mode: str = "parallel",
                 aggregation: str = "seed_replay", replay: str = "auto",
                 eval_loss: bool = True):
        if client_mode != "parallel":
            raise ValueError("async_mu_splitfed: the event-driven store "
                             "needs stacked per-client replicas "
                             "(client_mode='parallel')")
        if aggregation != "seed_replay":
            raise ValueError("async_mu_splitfed: the record store is the "
                             "seed-replay wire format; aggregation "
                             f"{aggregation!r} is not replayable")
        super().__init__(client_mode=client_mode, aggregation=aggregation,
                         replay=replay, eval_loss=eval_loss)

    def init_state(self, cfg, sfl, params, batch0):
        return events.init_store(sfl)

    def async_round_fn(self, cfg, sfl, params, store, batch, start_mask,
                       apply_w, key):
        return events.async_mu_splitfed_step(
            cfg, sfl, params, store, batch, start_mask, apply_w, key,
            replay=self.replay, eval_loss=self.eval_loss)

    def async_sparse_round_fn(self, cfg, sfl, params, store, batch,
                              start_client, start_slot, apply_slot,
                              apply_w, key):
        return events.async_mu_splitfed_sparse_step(
            cfg, sfl, params, store, batch, start_client, start_slot,
            apply_slot, apply_w, key, replay=self.replay,
            eval_loss=self.eval_loss)

    def time_model(self, delays, mask, sfl, sched):
        # event arrival times, not round maxima: the version ends at the
        # last pending ARRIVAL (delay + that client's own uplink), floored
        # by the τ·t_server server work. quorum=0 deliberately: this
        # per-row model is only consulted by the sync fallback modes,
        # which execute the full barrier and apply every contribution —
        # charging the K-th arrival there would understate the wait.
        # Quorum pacing is exact only with cross-version busy state, which
        # is what mode='async' reads off the compiled timeline instead.
        return events.quorum_round_time(delays, mask, sched.t_server,
                                        sfl.tau, quorum=0,
                                        t_comm=sched.t_comm,
                                        t_comm_scale=sched.t_comm_scale)

    def metrics_spec(self, cfg, sfl):
        if getattr(sfl, "timeline", "dense") == "sparse":
            return {"loss": (events.resolve_store_geometry(sfl)[0],)}
        return {"loss": (sfl.n_clients,)}


@register
class Gas(AlgorithmBase):
    """GAS-like async SFL with a carried activation buffer. ``fresh``
    selects where the freshness mask comes from: 'mask' (the schedule's
    participation·deadline row — the training driver's convention) or
    'median' (clients at/below the per-round median delay — Fig. 2)."""
    name = "gas"

    def __init__(self, aggregation: str = "dense", replay: str = "auto",
                 fresh: str = "mask"):
        if fresh not in ("mask", "median"):
            raise ValueError(f"gas: fresh must be 'mask'|'median', "
                             f"got {fresh!r}")
        self.aggregation = aggregation
        self.replay = replay
        self.fresh = fresh

    def init_state(self, cfg, sfl, params, batch0):
        return gas_init_state(cfg, sfl, params, batch0)

    def round_mask(self, sched, r):
        i = r % sched.n_rounds
        return (sched.fresh_median[i] if self.fresh == "median"
                else sched.masks[i])

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, state, m = gas_round(cfg, sfl, params, state, batch, mask,
                                     key, aggregation=self.aggregation,
                                     replay=self.replay)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_gas(delays, mask, sched.t_server, sched.t_gen,
                                    sched.comm_for(mask))

    def metrics_spec(self, cfg, sfl):
        return {"loss": (sfl.n_clients,), "server_deltas": (sfl.n_clients, 1),
                "client_delta": (sfl.n_clients,)}


@register
class FedAvg(AlgorithmBase):
    """First-order FedAvg (full model on every client, E local steps)."""
    name = "fedavg"

    def __init__(self, lr: Optional[float] = None, local_steps: int = 1,
                 optimizer: str = "sgd"):
        self.lr = lr
        self.local_steps = local_steps
        self.optimizer = optimizer

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        from repro.models import loss_fn
        first = (jax.tree.map(lambda a: a[:, 0], batch)
                 if self.local_steps > 1 else batch)
        loss0 = jax.vmap(lambda b: loss_fn(cfg, params, b))(first)
        params = fedavg_round(cfg, params, batch, mask,
                              self.lr if self.lr is not None else sfl.lr_client,
                              self.local_steps, self.optimizer,
                              eta_g=sfl.lr_global)
        return params, state, {"loss": loss0.astype(jnp.float32)}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_local_only(delays, mask, sched.comm_for(mask))


@register
class FedLora(FedAvg):
    """FedAvg over LoRA adapters only; the base params never move — the
    adapter tree is the engine state."""
    name = "fedlora"

    def __init__(self, rank: int = 4, alpha: float = 16.0,
                 lr: Optional[float] = None):
        super().__init__(lr=lr)
        self.rank = rank
        self.alpha = alpha

    def init_state(self, cfg, sfl, params, batch0):
        from repro.optim.lora import init_lora
        return init_lora(cfg, params, self.rank,
                         jax.random.PRNGKey(sfl.seed))

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        from repro.models import loss_fn
        from repro.optim.lora import apply_lora
        merged = apply_lora(params, state, self.alpha)
        loss0 = jax.vmap(lambda b: loss_fn(cfg, merged, b))(batch)
        lora = fedlora_round(cfg, params, state, batch, mask,
                             self.lr if self.lr is not None else sfl.lr_client,
                             self.alpha, eta_g=sfl.lr_global)
        return params, lora, {"loss": loss0.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# chunk-boundary controllers (adaptive τ / deadline policies)
# ---------------------------------------------------------------------------

class SchedWindow(NamedTuple):
    """What a Controller observes at a chunk boundary: the system-model
    rows of the rounds executed since its previous update. Async runs
    additionally carry ``quorum_wait`` — the per-version quorum waits from
    the compiled timeline (arrival of the K-th contribution, BEFORE the
    τ·t_server server floor — deliberately not the commit-to-commit
    duration, which includes that floor and would self-reinforce a τ
    planner): under event-driven commits THAT is the gap adaptive τ
    should fill with server steps, not the max active delay.

    ``telemetry`` carries the TelemetrySink records overlapping the window
    when run_rounds was given a sink — BOTH producers ('sim' and
    'measured'), so a controller chooses its clock (AdaptiveTau's
    ``source=``) instead of being wired to the simulator."""
    start: int
    stop: int
    delays: np.ndarray   # (C, M) simulated client compute times
    masks: np.ndarray    # (C, M) participation·deadline rows consumed
    t_server: float
    t_comm: float
    quorum_wait: Optional[np.ndarray] = None   # (C,) async quorum waits
    telemetry: Tuple[RoundTelemetry, ...] = ()  # sink records for the window


@runtime_checkable
class Controller(Protocol):
    """Chunk-boundary policy hook.

    ``update`` runs once per chunk, before it dispatches, with the window
    of rounds just executed (None at the very first boundary) and the last
    flushed ChunkInfo. The returned dict maps SFLConfig field names to new
    values ('tau', 'deadline', 'lr_server', ...) and is applied via
    dataclasses.replace; unchanged fields may be included (no-ops). A τ
    change re-traces the jit'd round body — the per-algo executable cache
    keyed on (mode, cfg, sfl) amortizes that across chunks, so revisited
    τ values reuse their compiled executables. An optional ``bind(sfl)``
    is called once at run start with the initial config.
    """

    def update(self, round_idx: int, window: Optional[SchedWindow],
               metrics: Optional["ChunkInfo"]) -> Dict[str, Any]: ...


class AdaptiveTau:
    """The paper's "adaptive tuning of τ" (§5) as an engine Controller.

    At each chunk boundary it EMA-smooths the observed straggler gap
    (max active delay per executed round) and re-plans
    τ* = t_straggler / t_server via straggler.plan_tau (Eq. 12). With
    ``couple_lr`` (default) the server lr keeps Thm 4.1's coupling:
    η_s·τ is held at its initial value, so a τ change rescales η_s and
    the per-round server drift stays stable. ``trace`` records the
    (round_idx, τ) decisions for analysis (benchmarks/fig5_adaptive_tau).

    ``source`` picks the clock the straggler gap is observed on:
    'sim' (default) reads the schedule's simulated delays / quorum waits
    from the window rows, the historical behaviour; 'measured' reads the
    measured-clock RoundTelemetry records from ``window.telemetry``
    (block_until_ready-bracketed per-round wall time) and falls back to
    the sim rows when no measured records cover the window — e.g. the
    first boundary, or a run without a sink.
    """

    def __init__(self, tau_max: int = 64, ema: float = 0.5,
                 couple_lr: bool = True, quantize: bool = False,
                 source: str = "sim"):
        if source not in ("sim", "measured"):
            raise ValueError(f"AdaptiveTau source must be 'sim'|'measured', "
                             f"got {source!r}")
        self.tau_max = tau_max
        self.ema = ema
        self.couple_lr = couple_lr
        self.source = source
        self.quantize = quantize      # snap τ to powers of two: bounds the
        self.t_hat: Optional[float] = None        # number of distinct jit
        self._eta_step: Optional[float] = None    # executables (η_s·τ cached
        self.trace: List[Tuple[int, int]] = []    # at bind time)

    def bind(self, sfl) -> None:
        if self.couple_lr and self._eta_step is None:
            self._eta_step = sfl.lr_server * sfl.tau

    # checkpointable controller state (engine saves it in the checkpoint
    # metadata; apply_resume_overrides restores it)
    def state_dict(self) -> Dict[str, Any]:
        return {"t_hat": self.t_hat, "eta_step": self._eta_step}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.t_hat = d.get("t_hat")
        self._eta_step = d.get("eta_step")

    def _observed(self, window) -> np.ndarray:
        if self.source == "measured":
            meas = [r for r in getattr(window, "telemetry", ()) or ()
                    if r.source == "measured"]
            if meas:
                return np.concatenate([np.asarray(r.durations, np.float64)
                                       for r in meas])
        if window.quorum_wait is not None:
            # async window: the observed gap is the quorum wait — how long
            # the server sat idle before the K-th arrival let it commit
            return np.asarray(window.quorum_wait, np.float64)
        act = np.where(window.masks > 0, window.delays, -np.inf)
        per_round = act.max(axis=1)
        return np.where(np.isfinite(per_round), per_round, 0.0)

    def update(self, round_idx, window, metrics):
        if window is None or window.delays.size == 0:
            return {}
        per_round = self._observed(window)
        obs = float(per_round.mean())
        self.t_hat = (obs if self.t_hat is None
                      else self.ema * obs + (1.0 - self.ema) * self.t_hat)
        tau = strag.plan_tau(self.t_hat, window.t_server, self.tau_max)
        if self.quantize:
            tau = min(1 << int(round(np.log2(max(tau, 1)))), self.tau_max)
        self.trace.append((round_idx, tau))
        out = {"tau": tau}
        if self._eta_step is not None:
            out["lr_server"] = self._eta_step / tau
        return out


class AdaptiveQuorum:
    """Graceful-degradation controller: resize the commit quorum K from
    observed fault pressure (core/faults.py).

    At each chunk boundary it reads the window's simulator RoundTelemetry
    records (``SchedWindow.telemetry``) — started dispatches vs.
    contributions lost to crashes, exhausted retries, checksum drops, and
    ring evictions — EMA-smooths the observed delivery rate, and re-plans
    K ≈ ceil(K0 · delivered/started). When a fifth of the fleet's fetches
    die, holding out for the configured K would push every commit into
    the quorum_timeout escape; shrinking K to what the fleet can actually
    fill keeps commits quorum-paced. When delivery recovers the quorum
    grows back toward its configured value. K is clipped to
    [k_min, K0] — never above the initial quorum: the ring geometry (and
    the healthy-state semantics) are sized for K0. ``trace`` records the
    (round_idx, K) decisions, mirroring AdaptiveTau.
    """

    def __init__(self, k_min: int = 1, ema: float = 0.5):
        if k_min < 1:
            raise ValueError(f"AdaptiveQuorum k_min must be >= 1, "
                             f"got {k_min}")
        self.k_min = int(k_min)
        self.ema = ema
        self.k0: Optional[int] = None
        self.rate: Optional[float] = None      # EMA'd delivery rate
        self.trace: List[Tuple[int, int]] = []

    def bind(self, sfl) -> None:
        if self.k0 is None:
            if sfl.quorum <= 0:
                raise ValueError(
                    "AdaptiveQuorum needs a finite initial quorum "
                    "(sfl.quorum > 0): K0 anchors the [k_min, K0] range")
            self.k0 = int(sfl.quorum)

    def state_dict(self) -> Dict[str, Any]:
        return {"k0": self.k0, "rate": self.rate}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.k0 = d.get("k0")
        self.rate = d.get("rate")

    def update(self, round_idx, window, metrics):
        if window is None or self.k0 is None:
            return {}
        recs = [r for r in getattr(window, "telemetry", ()) or ()
                if r.source == "sim"]
        started = sum(r.started for r in recs)
        if not started:                  # no sink, or a zero-fault window
            return {}                    # with no dispatch accounting
        dropped = sum(r.crashed + r.lost + r.corrupt + r.evicted
                      for r in recs)
        obs = max(0.0, 1.0 - dropped / started)
        self.rate = (obs if self.rate is None
                     else self.ema * obs + (1.0 - self.ema) * self.rate)
        k = int(np.clip(int(np.ceil(self.k0 * self.rate)),
                        self.k_min, self.k0))
        self.trace.append((round_idx, k))
        return {"quorum": k}


# ---------------------------------------------------------------------------
# the fused multi-round driver
# ---------------------------------------------------------------------------

class EngineResult(NamedTuple):
    params: Params
    state: State
    metrics: Dict[str, np.ndarray]  # per-round stacks, leading dim = rounds run
    round_loss: np.ndarray          # (rounds,) mask-weighted mean client loss
    round_times: np.ndarray         # (rounds,) simulated per-round wall-clock
    sim_time: float                 # sum(round_times)
    tau_per_round: Optional[np.ndarray] = None  # (rounds,) τ each round;
    #                                 None only when constructed by hand —
    #                                 run_rounds always fills it. Guard
    #                                 before arithmetic all the same.


class ChunkInfo(NamedTuple):
    """Everything a chunk_callback needs about the rounds just flushed —
    engine-computed, so drivers never re-derive losses/times/masks."""
    start: int                      # first absolute round in the chunk
    stop: int                       # one past the last round
    metrics: Dict[str, np.ndarray]  # host-flushed stacks, leading dim C
    masks: np.ndarray               # (C, M) the mask rows the rounds consumed
    round_loss: np.ndarray          # (C,) mask-weighted mean client loss
    round_times: np.ndarray         # (C,) simulated per-round wall-clock


def fold_in_keys(key, start: int, n: int) -> jax.Array:
    """(n, 2) stacked per-round keys: keys[i] = fold_in(key, start + i) —
    identical to what the legacy loops derived one round at a time."""
    return jax.vmap(lambda r: jax.random.fold_in(key, r))(
        jnp.arange(start, start + n))


def make_chunk_fn(algo: Algorithm, cfg: ModelConfig, sfl: SFLConfig):
    """The fused multi-round step: scan algo.round_fn over a chunk of
    precomputed (batches, masks, keys) rows. Shared with the perf-ladder
    cell builder (launch/steps.py train_multi)."""
    def run_chunk(params, state, batches, masks, keys):
        def body(carry, xs):
            p, s = carry
            b, m, k = xs
            p, s, met = algo.round_fn(cfg, sfl, p, s, b, m, k)
            return (p, s), met
        (params, state), mets = jax.lax.scan(body, (params, state),
                                             (batches, masks, keys))
        return params, state, mets
    return run_chunk


def make_async_chunk_fn(algo: Algorithm, cfg: ModelConfig, sfl: SFLConfig):
    """The fused multi-version async step: scan algo.async_round_fn over a
    chunk of precomputed (batches, start_masks, apply_ws, keys) rows from
    the compiled event timeline, carrying (params, record store)."""
    def run_chunk(params, store, batches, start_masks, apply_ws, keys):
        def body(carry, xs):
            p, s = carry
            b, sm, aw, k = xs
            p, s, met = algo.async_round_fn(cfg, sfl, p, s, b, sm, aw, k)
            return (p, s), met
        (params, store), mets = jax.lax.scan(
            body, (params, store), (batches, start_masks, apply_ws, keys))
        return params, store, mets
    return run_chunk


def make_sparse_chunk_fn(algo: Algorithm, cfg: ModelConfig, sfl: SFLConfig):
    """The fused multi-version sparse-async step: scan
    algo.async_sparse_round_fn over the streamed timeline's (C, K) commit-
    batch rows — pre-gathered client batches, start scatter indices into
    the ring store, and apply gather indices + weights — carrying
    (params, ring store)."""
    def run_chunk(params, store, batches, start_client, start_slot,
                  apply_slot, apply_ws, keys):
        def body(carry, xs):
            p, s = carry
            b, sc, ss, asl, aw, k = xs
            p, s, met = algo.async_sparse_round_fn(cfg, sfl, p, s, b, sc,
                                                   ss, asl, aw, k)
            return (p, s), met
        (params, store), mets = jax.lax.scan(
            body, (params, store),
            (batches, start_client, start_slot, apply_slot, apply_ws, keys))
        return params, store, mets
    return run_chunk


def _stack_leaves(*xs):
    # host (numpy) leaves stack on host then upload once; device leaves
    # stack on-device — never bounce device->host->device
    if all(isinstance(x, np.ndarray) for x in xs):
        return jnp.asarray(np.stack(xs))
    return jnp.stack([jnp.asarray(x) for x in xs])


def _stack_chunk(batch_fn, r0: int, n: int):
    """Stack n rounds of per-client batches -> leaves (n, M, ...)."""
    return jax.tree.map(_stack_leaves, *[batch_fn(r0 + i) for i in range(n)])


def _stack_sparse_chunk(batch_fn, r0: int, start_clients: np.ndarray,
                        subset_fn=None, batch_put=None):
    """Stack a sparse chunk's batch rows -> leaves (C, K, ...): per
    version, gather ONLY the starting clients' rows from that round's
    batch (pad rows re-read client 0 — their records land in the ring's
    dropped pad slot, so they are never applied). The device never sees an
    (M, ...) batch, which is what keeps upload volume O(K) per version.

    ``subset_fn(round, client_ids)`` (e.g. FederatedLoader.subset_batch)
    upgrades the gather to O(K) *staging*: only the K starting rows are
    ever materialized on the host — the fleet-width batch is never built.
    Pad rows (-1) clip to client 0, exactly the gather path's convention,
    so both paths are bit-identical. ``batch_put`` (e.g. a NamedSharding
    device_put from launch/fleet.py) places the stacked (C, K, ...) leaves
    before the scan consumes them."""
    rounds = []
    for j in range(start_clients.shape[0]):
        idx = np.clip(start_clients[j], 0, None)
        if subset_fn is not None:
            rounds.append(subset_fn(r0 + j, idx))
        else:
            b = batch_fn(r0 + j)
            rounds.append(jax.tree.map(
                lambda x: x[idx] if isinstance(x, np.ndarray)
                else jnp.take(jnp.asarray(x), jnp.asarray(idx), axis=0), b))
    out = jax.tree.map(_stack_leaves, *rounds)
    return out if batch_put is None else batch_put(out)


def _copy_tree(tree):
    # donation safety: the caller keeps its own params/state buffers
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


def _tree_nbytes(tree) -> int:
    """Bytes staged for a chunk: sum of leaf .nbytes (host or device)."""
    return int(sum(getattr(x, "nbytes", 0) for x in jax.tree.leaves(tree)))


def _cached_jit(algo: Algorithm, mode: str, cfg: ModelConfig, sfl: SFLConfig,
                build: Callable):
    """Per-algorithm-instance jit cache: repeated run_rounds calls with the
    same (algo, cfg, sfl) reuse the compiled executables instead of
    re-tracing a fresh closure every call (jax.jit caches by function
    identity, which a fresh lambda defeats)."""
    cache = getattr(algo, "_engine_jit_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(algo, "_engine_jit_cache", cache)
    k = (mode, cfg, sfl)
    if k not in cache:
        cache[k] = build()
    return cache[k]


def _has_state(state) -> bool:
    return bool(jax.tree.leaves(state))


def _ckpt_tree(params, state):
    """What the engine checkpoints: params alone for stateless algorithms
    (back-compatible with pre-existing checkpoints), else a
    {'params','state'} bundle so resume is exact for stateful algorithms
    (GAS activation buffer, FedLoRA adapters)."""
    return {"params": params, "state": state} if _has_state(state) else params


def restore_run(checkpointer, algorithm: Union[str, Algorithm],
                cfg: ModelConfig, sfl: SFLConfig, params: Params,
                batch_fn: Callable[[int], Batch], *,
                step: Optional[int] = None,
                **algo_opts) -> Tuple[Params, State, dict]:
    """Restore an engine checkpoint for resume: (params, state, meta).

    Stateful algorithms restore their engine state alongside params when
    the checkpoint carries the {'params','state'} bundle (the state
    template — and hence one batch — is only materialized on that path).
    Legacy params-only checkpoints return state=None: run_rounds then
    re-inits from the first resumed round's batch, the historical
    behaviour. Continue with ``run_rounds(..., state=state,
    start_round=meta['step'] + 1)``; controller-driven runs should also
    pass meta through ``apply_resume_overrides``.
    """
    from repro.ckpt import read_meta
    algo = get_algorithm(algorithm, **algo_opts)
    checkpointer.wait()
    meta = read_meta(checkpointer.dir, step)
    start = meta["step"] + 1
    if meta.get("metadata", {}).get("has_state"):
        state = algo.init_state(cfg, sfl, params,
                                jax.tree.map(jnp.asarray, batch_fn(start)))
        bundle, meta = checkpointer.restore(
            {"params": params, "state": state}, meta["step"])
        return bundle["params"], bundle["state"], meta
    params, meta = checkpointer.restore(params, meta["step"])
    return params, None, meta


def apply_resume_overrides(sfl: SFLConfig, meta: dict,
                           controller: Optional[Controller] = None
                           ) -> SFLConfig:
    """Re-apply a resumed run's controller decisions.

    Engine checkpoints record the SFLConfig fields a controller had
    overridden by save time (metadata['controller_overrides']) and the
    controller's own state (metadata['controller_state'], via its
    state_dict). This replays both onto the resume configuration so the
    run continues at the adapted τ / lrs with the controller's EMA intact
    instead of silently restarting from the CLI values. (The first
    post-resume chunk has no observed window, so it keeps the restored τ;
    a controller that overrode 'deadline' should also rebuild its
    schedule with that deadline.)
    """
    md = meta.get("metadata", {})
    overrides = md.get("controller_overrides") or {}
    if overrides:
        sfl = dataclasses.replace(sfl, **overrides)
    cs = md.get("controller_state")
    if controller is not None and cs and hasattr(controller,
                                                 "load_state_dict"):
        controller.load_state_dict(cs)
    return sfl


def run_rounds(algorithm: Union[str, Algorithm], cfg: ModelConfig,
               sfl: SFLConfig, params: Params, batch_fn: Callable[[int], Batch],
               schedule: strag.Schedule, key, *, rounds: int,
               start_round: int = 0, chunk_size: int = 8,
               mode: str = "scan", state: Optional[State] = None,
               checkpointer=None, ckpt_every: int = 0,
               chunk_callback: Optional[Callable] = None,
               controller: Optional[Controller] = None,
               tau_history: Optional[List[int]] = None,
               quorum_history: Optional[List[int]] = None,
               batch_subset_fn: Optional[Callable] = None,
               batch_put: Optional[Callable] = None,
               telemetry: Optional[TelemetrySink] = None,
               **algo_opts) -> EngineResult:
    """Run rounds [start_round, rounds) of ``algorithm``.

    batch_fn(r) returns the round-r host batch (leaves with leading M dim;
    must be stateless in r so restarts are exact). ``schedule`` provides the
    (R, M) delay/mask rows (cyclic if shorter than the run) and the
    wall-clock knobs. ``key`` is the run's base PRNG key; round r uses
    fold_in(key, r).

    mode='scan': rounds execute in chunks of ``chunk_size`` as one jit'd
    lax.scan per chunk with params/state donated between chunks; metrics
    flush to host (and ``chunk_callback(ChunkInfo, params, state)`` /
    checkpointing fire) only at chunk boundaries, which are aligned to
    ckpt_every. mode='python': the legacy per-round loop — one jit call +
    host sync per round (equivalence/bench baseline); it shares the same
    chunk segmentation so controller decisions land on identical
    boundaries in both modes. mode='async': event-driven semi-async
    (core/events.py) — the schedule is compiled into an arrival-ordered
    timeline, each "round" is one quorum-committed server version
    (sfl.quorum / sfl.staleness_discount are the policy knobs), the
    in-flight record store rides as engine state, and round_times are the
    timeline's commit-to-commit durations; needs an async-capable
    algorithm (async_mu_splitfed). With quorum 0 (= wait for all) and
    discount 1.0 it reproduces mode='scan' exactly.

    sfl.timeline picks the async backend: 'dense' precompiles the whole
    (V, M) timeline up front (small-M reference); 'sparse' streams
    (chunk, k_max) commit batches from the heap DES while the device
    scans the previous chunk, with the in-flight records in a bounded
    arrival-slot ring (events.resolve_store_geometry) — same semantics,
    O(k_max · chunk) host rows instead of O(V · M), and per-version
    batch upload gathered down to the starting clients.

    ``controller`` (e.g. AdaptiveTau) runs at every chunk boundary and may
    override SFLConfig fields for the remaining rounds — 'tau' re-plans the
    unbalanced server updates (re-jit amortized by the per-algo executable
    cache), 'deadline' re-derives the straggler-drop masks from the
    schedule's delay rows. Masks, wall-clock round times, and the τ trace
    (EngineResult.tau_per_round) always reflect what was actually applied.

    ``telemetry`` (a repro.obs TelemetrySink) turns on BOTH producers at
    chunk boundaries: 'sim' records carry the simulator's account of the
    chunk (durations bit-identical to ChunkInfo.round_times, async quorum
    waits, per-cohort arrival latencies) and 'measured' records carry the
    measured clock (block_until_ready-bracketed chunk dispatch, host
    staging seconds/bytes, DES-prefetch overlap). Controllers see the
    window's records via SchedWindow.telemetry. With telemetry=None
    (default) no clock reads or extra syncs happen on the hot path.

    ``sfl.faults`` (a core/faults.py FaultPlan) perturbs the async event
    stream — crash-after-fetch, lossy delivery with up to
    ``sfl.max_retries`` retransmissions, duplication, checksum-dropped
    corruption — and ``sfl.quorum_timeout`` caps how long a commit waits
    for its quorum before proceeding with whatever arrived (weights
    renormalized). None / FaultPlan.none() is bit-exact with the clean
    engine. AdaptiveQuorum (with a telemetry sink) shrinks/grows the
    commit quorum from the observed delivery rate.

    Checkpoints save at step = round index of the last completed round in
    the chunk (stateful algorithms bundle their engine state — see
    restore_run); resume via restore_run and start_round=step+1. Async
    controller runs additionally record the per-version τ / quorum traces
    in the checkpoint metadata ('tau_per_version' / 'quorum_per_version'):
    pass them back as ``tau_history`` / ``quorum_history`` on resume so
    the timeline prefix recompiles with the values that actually executed.
    """
    algo = get_algorithm(algorithm, **algo_opts)
    if mode not in ("scan", "python", "async"):
        raise ValueError(f"run_rounds: mode must be 'scan'|'python'|'async', "
                         f"got {mode!r}")
    if mode == "async" and not hasattr(algo, "async_round_fn"):
        raise ValueError(
            f"mode='async' needs an async-capable algorithm (e.g. "
            f"'async_mu_splitfed'); {algo.name!r} has no async_round_fn")
    if sfl.timeline not in ("dense", "sparse"):
        raise ValueError(f"run_rounds: sfl.timeline must be 'dense'|"
                         f"'sparse', got {sfl.timeline!r}")
    sparse = sfl.timeline == "sparse"
    if sparse and mode != "async":
        raise ValueError(
            "timeline='sparse' is the streaming semi-async path; run it "
            "with mode='async' (the sync modes scan dense schedule rows)")
    if sparse and not hasattr(algo, "async_sparse_round_fn"):
        raise ValueError(f"timeline='sparse' needs an algorithm with "
                         f"async_sparse_round_fn; {algo.name!r} has none")
    if batch_subset_fn is not None and not sparse:
        raise ValueError(
            "batch_subset_fn is the sparse timeline's O(K) staging hook; "
            "the dense modes consume fleet-width batches — set "
            "sfl.timeline='sparse' (with mode='async') to use it")
    if batch_put is not None and not sparse:
        raise ValueError(
            "batch_put places sparse (C, K, ...) staged chunks; it has no "
            "effect outside timeline='sparse'")
    n_run = rounds - start_round
    if n_run <= 0:
        empty = np.zeros((0,), np.float64)
        return EngineResult(params, state, {}, empty, empty, 0.0,
                            np.zeros((0,), np.int64))

    if state is None:
        # the subset path never materializes a fleet-width batch, not even
        # for the state template: sparse-capable algorithms size their
        # state from sfl (the ring store), so a 1-row probe batch suffices
        batch0 = (batch_subset_fn(start_round, np.zeros(1, np.int64))
                  if batch_subset_fn is not None else batch_fn(start_round))
        state = algo.init_state(cfg, sfl, params,
                                jax.tree.map(jnp.asarray, batch0))

    R = schedule.n_rounds
    cohort_bounds = events._cohort_bounds_of(schedule)
    rows = list(range(start_round, rounds))
    mask_of = getattr(algo, "round_mask",
                      lambda sched, r: sched.masks[r % sched.n_rounds])
    sched_eff = schedule                 # re-derived on controller deadline
    # (n_run, M) mask rows feed sync round_times and controller windows;
    # the sparse path never materializes them — windows rebuild rows on
    # demand from the mask-epoch list below
    time_masks = (None if sparse else
                  np.stack([sched_eff.masks[r % R] for r in rows]))
    timeline: Optional[events.Timeline] = None
    stream: Optional[events.TimelineStream] = None
    qwaits: Optional[np.ndarray] = None
    # fault / degradation counter columns surfaced to RoundTelemetry —
    # sparse fills fcounts from the streamed rows; dense reads the
    # compiled timeline's (V,) columns directly (no ring -> no evictions)
    fault_cols = ("started", "evicted", "crashed", "lost", "corrupt",
                  "dups", "retries", "timeouts")
    fcounts: Optional[np.ndarray] = None
    if mode == "async":
        # compile the semi-async event timeline for the WHOLE run (from
        # version 0, so a resumed run sees the identical prefix and slices
        # its rows); the engine scans its per-version form as data.
        # ``masks`` become the normalized staleness-discounted apply
        # weights — round_loss / ChunkInfo weighting carries over as-is.
        # ``tau_history`` replays a resumed controller run's per-version τ
        # onto the prefix (checkpoint metadata 'tau_per_version'): the DES
        # is only prefix-stable if the prefix is compiled with the τ that
        # actually executed, otherwise the restored record store would
        # meet inconsistent apply weights.
        taus_v = np.full(rounds, sfl.tau, np.int64)
        if tau_history is not None:
            h = np.asarray(tau_history, np.int64)[:rounds]
            taus_v[:len(h)] = h
        # per-version quorum, same replay contract as taus_v: resume must
        # recompile the prefix with the K that actually committed
        # (checkpoint metadata 'quorum_per_version' -> quorum_history)
        quorums_v = np.full(rounds, sfl.quorum, np.int64)
        if quorum_history is not None:
            h = np.asarray(quorum_history, np.int64)[:rounds]
            quorums_v[:len(h)] = h
        if sparse:
            # streaming timeline: no (V, M) rows, no (V, ·) precompute.
            # The DES streams (C, k_max) commit batches chunk-by-chunk;
            # skip(start_round) replays the prefix so the ring/slot state
            # at resume is identical to the original run's. Deadline
            # re-plans append (from_version, schedule) epochs instead of
            # rewriting dense mask rows.
            k_geo, cap_geo = events.resolve_store_geometry(sfl)
            mask_epochs: List[Tuple[int, strag.Schedule]] = [(0, sched_eff)]

            def _mask_row_at(v: int) -> np.ndarray:
                sch = mask_epochs[0][1]
                for v0, cand in mask_epochs:
                    if v >= v0:
                        sch = cand
                return sch.masks[v % R]

            def _new_stream(skip_to: int) -> events.TimelineStream:
                st = events.TimelineStream(
                    sched_eff, rounds, quorum=sfl.quorum,
                    discount=sfl.staleness_discount, taus=taus_v,
                    k_max=k_geo, capacity=cap_geo,
                    mask_row_fn=_mask_row_at, quorums=quorums_v,
                    faults=sfl.faults,
                    quorum_timeout=sfl.quorum_timeout,
                    max_retries=sfl.max_retries)
                st.skip(skip_to)
                return st

            stream = _new_stream(start_round)
            masks = np.zeros((n_run, k_geo), np.float32)
            round_times = np.zeros(n_run, np.float64)
            qwaits = np.zeros(n_run, np.float64)
            fcounts = np.zeros((n_run, len(fault_cols)), np.int64)
        else:
            amask_rows = np.stack([sched_eff.masks[v % R]
                                   for v in range(rounds)])
            with span("engine.compile_timeline", versions=rounds):
                timeline = events.compile_timeline(
                    sched_eff, rounds, quorum=quorums_v,
                    discount=sfl.staleness_discount, tau=taus_v,
                    mask_rows=amask_rows, faults=sfl.faults,
                    quorum_timeout=sfl.quorum_timeout,
                    max_retries=sfl.max_retries)
            masks = timeline.apply_w[start_round:rounds].copy()
            start_masks = timeline.start_mask[start_round:rounds].copy()
            round_times = timeline.durations[start_round:rounds].copy()
    else:
        masks = np.stack([mask_of(sched_eff, r) for r in rows])
        round_times = np.array([algo.time_model(sched_eff.delays[r % R],
                                                time_masks[i], sfl, sched_eff)
                                for i, r in enumerate(rows)])
    tau_used = np.full(n_run, sfl.tau, np.int64)
    keys = fold_in_keys(key, start_round, n_run)

    # chunk segmentation (aligned to ckpt_every) — shared by both modes and
    # by the controller's update boundaries
    segments: List[Tuple[int, int]] = []
    r = start_round
    while r < rounds:
        C = min(chunk_size, rounds - r)
        if ckpt_every:
            C = min(C, ckpt_every - r % ckpt_every)
        segments.append((r, r + C))
        r += C

    if controller is not None and hasattr(controller, "bind"):
        controller.bind(sfl)

    chunks: list = []
    last_info: Optional[ChunkInfo] = None
    applied: Dict[str, Any] = {}    # controller overrides in effect

    def ckpt_meta(**extra):
        md = {"has_state": _has_state(state), **extra}
        if controller is not None:
            if applied:             # values must be JSON-serializable
                md["controller_overrides"] = dict(applied)
            if hasattr(controller, "state_dict"):
                md["controller_state"] = controller.state_dict()
            if mode == "async":
                # per-version τ / K traces: resume must recompile the
                # timeline prefix with the values that actually executed
                # (tau_history / quorum_history)
                md["tau_per_version"] = [int(t) for t in taus_v]
                md["quorum_per_version"] = [int(q) for q in quorums_v]
        return md

    def seg_info(r0, r1):
        i0, i1 = r0 - start_round, r1 - start_round
        seg = chunks[-(r1 - r0):]
        host = {k2: np.concatenate([c[k2] for c in seg]) for k2 in seg[0]}
        m = masks[i0:i1]
        rl = ((host["loss"] * m).sum(1)
              / np.maximum(m.sum(1), 1.0)).astype(np.float64)
        return ChunkInfo(r0, r1, host, m, rl, round_times[i0:i1])

    def _cohort_arrival(r0, r1):
        """Per-cohort mean arrival latency (delay + uplink) of the window's
        active clients — the observed compute/comm ratio input the HASFL
        cut-layer co-planner needs. None on lazy/sparse schedules, which
        never materialize fleet-width rows."""
        if sparse or not hasattr(sched_eff, "delays"):
            return None
        i0, i1 = r0 - start_round, r1 - start_round
        d = np.stack([sched_eff.delays[rr % R] for rr in range(r0, r1)])
        arr = d + events._comm_of(sched_eff)[None, :]
        m = time_masks[i0:i1]
        out = np.zeros(len(cohort_bounds), np.float64)
        for ci, (cs, ce) in enumerate(cohort_bounds):
            w = m[:, cs:ce]
            tot = w.sum()
            out[ci] = float((arr[:, cs:ce] * w).sum() / tot) if tot else 0.0
        return out

    def _sim_emit(r0, r1):
        # the simulator producer: durations are the SAME slice ChunkInfo
        # carries (the bit-consistency gate in tests/test_obs.py), quorum
        # waits the same rows the controller window reads
        i0, i1 = r0 - start_round, r1 - start_round
        counts: Dict[str, int] = {}
        if mode != "async":
            qw = None
        elif sparse:
            qw = qwaits[i0:i1].copy()
            counts = {f: int(fcounts[i0:i1, j].sum())
                      for j, f in enumerate(fault_cols)}
        else:
            qw = timeline.quorum_wait[r0:r1].copy()
            for f in fault_cols:
                col = getattr(timeline, f, None)
                if col is not None:
                    counts[f] = int(col[r0:r1].sum())
        telemetry.emit(RoundTelemetry(
            r0, r1, "sim", mode, round_times[i0:i1].copy(), quorum_wait=qw,
            cohort_arrival=_cohort_arrival(r0, r1), **counts))

    def flush(mets, r0, r1):
        nonlocal last_info
        host = jax.tree.map(np.asarray, mets)      # host sync: chunk boundary
        chunks.append(host)
        i0, i1 = r0 - start_round, r1 - start_round
        m = masks[i0:i1]
        rl = ((host["loss"] * m).sum(1)
              / np.maximum(m.sum(1), 1.0)).astype(np.float64)
        last_info = ChunkInfo(r0, r1, host, m, rl, round_times[i0:i1])
        if telemetry is not None:
            _sim_emit(r0, r1)
        if chunk_callback is not None:
            chunk_callback(last_info, params, state)

    def controller_step(seg_idx):
        """Apply the controller's SFLConfig overrides for rounds >= this
        segment; re-derive masks / wall-clock rows they affect. In async
        mode the future of the event timeline is recompiled — the DES is
        prefix-stable, so the already-executed versions are untouched."""
        nonlocal sfl, sched_eff, timeline, stream, state
        r0 = segments[seg_idx][0]
        window = None
        if seg_idx > 0:
            p0, p1 = segments[seg_idx - 1]
            i0, i1 = p0 - start_round, p1 - start_round
            if sparse:
                wmasks = np.stack([_mask_row_at(rr)
                                   for rr in range(p0, p1)])
                qw = qwaits[i0:i1].copy()
            else:
                wmasks = time_masks[i0:i1]
                qw = (timeline.quorum_wait[p0:p1].copy()
                      if timeline is not None else None)
            window = SchedWindow(
                p0, p1,
                np.stack([sched_eff.delays[rr % R] for rr in range(p0, p1)]),
                wmasks, sched_eff.t_server, sched_eff.t_comm, qw,
                telemetry=(telemetry.window(p0, p1)
                           if telemetry is not None else ()))
        upd = controller.update(r0, window, last_info) or {}
        changed = {k: v for k, v in upd.items() if getattr(sfl, k) != v}
        if not changed:
            return
        if mode == "async" and "staleness_discount" in changed:
            raise ValueError(
                "controllers cannot override staleness_discount mid-run: "
                "already-applied records carry its weights, so the "
                "timeline is not prefix-stable under that change")
        if sparse and "quorum" in changed:
            # the ring geometry was resolved from the INITIAL config and
            # is baked into the store / staged-row shapes; pin the
            # resolved values so the new quorum cannot re-derive a
            # different k_max/capacity under the auto (0) knobs
            if sfl.k_max != k_geo:
                changed["k_max"] = k_geo
            if sfl.ring_capacity != cap_geo:
                changed["ring_capacity"] = cap_geo
        applied.update(changed)
        sfl = dataclasses.replace(sfl, **changed)
        i = r0 - start_round
        if "deadline" in changed:
            nd = np.stack([strag.deadline_mask(sched_eff.delays[j],
                                               sfl.deadline)
                           for j in range(R)])
            sched_eff = dataclasses.replace(
                sched_eff, deadline=nd, masks=sched_eff.participation * nd)
            if sparse:
                # future versions read the re-derived masks through the
                # epoch list; past versions keep the masks they executed
                mask_epochs.append((r0, sched_eff))
            else:
                for j, rr in enumerate(rows[i:], start=i):
                    time_masks[j] = sched_eff.masks[rr % R]
            if mode != "async":
                for j, rr in enumerate(rows[i:], start=i):
                    masks[j] = mask_of(sched_eff, rr)
        if mode == "async":
            if {"tau", "deadline", "quorum"} & set(changed):
                # piecewise knob change: versions >= r0 take the new
                # values, the executed prefix keeps what it ran with
                taus_v[r0:] = sfl.tau
                quorums_v[r0:] = sfl.quorum
                if sparse:
                    # rebuild the stream and replay the (prefix-stable)
                    # DES to r0 — already-flushed rows are untouched and
                    # the ring state at r0 is reproduced exactly
                    stream = _new_stream(r0)
                else:
                    amask_rows[r0:] = np.stack(
                        [sched_eff.masks[v % R]
                         for v in range(r0, rounds)])
                    timeline = events.compile_timeline(
                        sched_eff, rounds, quorum=quorums_v,
                        discount=sfl.staleness_discount, tau=taus_v,
                        mask_rows=amask_rows, faults=sfl.faults,
                        quorum_timeout=sfl.quorum_timeout,
                        max_retries=sfl.max_retries)
                    masks[i:] = timeline.apply_w[r0:rounds]
                    start_masks[i:] = timeline.start_mask[r0:rounds]
                    round_times[i:] = timeline.durations[r0:rounds]
            if "tau" in changed:
                # the record store's τ axis is static per executable
                state = events.resize_store(state, sfl.tau)
        else:
            for j, rr in enumerate(rows[i:], start=i):
                round_times[j] = algo.time_model(sched_eff.delays[rr % R],
                                                 time_masks[j], sfl,
                                                 sched_eff)
        tau_used[i:] = sfl.tau

    if mode == "python":
        for si, (r0, r1) in enumerate(segments):
            if controller is not None:
                controller_step(si)
            round_jit = _cached_jit(
                algo, "python", cfg, sfl,
                lambda sfl=sfl: jax.jit(lambda p, s, b, m, k: algo.round_fn(
                    cfg, sfl, p, s, b, m, k)))
            t_seg = perf_counter() if telemetry is not None else 0.0
            for rr in range(r0, r1):
                i = rr - start_round
                b = jax.tree.map(jnp.asarray, batch_fn(rr))
                params, state, met = round_jit(params, state, b,
                                               jnp.asarray(masks[i]), keys[i])
                flush(jax.tree.map(lambda a: a[None], met), rr, rr + 1)
                if (checkpointer is not None and ckpt_every
                        and (rr + 1) % ckpt_every == 0 and rr + 1 < rounds):
                    checkpointer.save(rr, _ckpt_tree(params, state),
                                      metadata=ckpt_meta())
            if telemetry is not None:
                # per-round flush above is the host sync, so the segment
                # bracket needs no extra block_until_ready
                dt, C = perf_counter() - t_seg, r1 - r0
                telemetry.emit(RoundTelemetry(
                    r0, r1, "measured", mode, np.full(C, dt / C),
                    dispatch_seconds=dt))
            if controller is not None and r1 - r0 > 1:
                # controllers see the whole segment's metrics, exactly as
                # in scan mode (flush above is per round here)
                last_info = seg_info(r0, r1)
    else:
        # fused on-device modes: 'scan' over schedule rows, dense 'async'
        # over the compiled timeline's (start_mask, apply_w) rows, sparse
        # 'async' over streamed (C, k_max) commit batches — one loop, the
        # modes differ only in the chunk body and its scanned inputs
        make_fn = (make_sparse_chunk_fn if sparse else
                   make_async_chunk_fn if mode == "async" else make_chunk_fn)
        params, state = _copy_tree(params), _copy_tree(state)
        pending_rows: Optional[events.SparseRows] = None
        tele = telemetry is not None
        for si, (r0, r1) in enumerate(segments):
            if controller is not None:
                controller_step(si)
            chunk_jit = _cached_jit(
                algo, mode, cfg, sfl,
                lambda sfl=sfl: jax.jit(make_fn(algo, cfg, sfl),
                                        donate_argnums=(0, 1)))
            i, C = r0 - start_round, r1 - r0
            # measured-producer bracketing: host staging is [t_host,
            # t_disp), the device chunk is [t_disp, t_sync) closed by
            # block_until_ready — the DES prefetch stays INSIDE that
            # dispatch window (that's the overlap being measured), never
            # after it, so turning telemetry on cannot serialize the
            # host/device pipeline it is measuring.
            t_host = perf_counter() if tele else 0.0
            overlap = 0.0
            if sparse:
                with span("engine.des_take", start=r0, stop=r1):
                    rows_c = (pending_rows if pending_rows is not None
                              else stream.take(C))
                pending_rows = None
                masks[i:i + C] = rows_c.apply_w
                round_times[i:i + C] = rows_c.durations
                qwaits[i:i + C] = rows_c.quorum_wait
                for j, f in enumerate(fault_cols):
                    fcounts[i:i + C, j] = getattr(rows_c, f)
                with span("engine.stage", start=r0, stop=r1):
                    staged = _stack_sparse_chunk(
                        batch_fn, r0, rows_c.start_client,
                        subset_fn=batch_subset_fn, batch_put=batch_put)
                t_disp = perf_counter() if tele else 0.0
                with span("engine.dispatch", start=r0, stop=r1):
                    params, state, mets = chunk_jit(
                        params, state, staged,
                        jnp.asarray(rows_c.start_client),
                        jnp.asarray(rows_c.start_slot),
                        jnp.asarray(rows_c.apply_slot),
                        jnp.asarray(rows_c.apply_w), keys[i:i + C])
                if controller is None and si + 1 < len(segments):
                    # host/device overlap: JAX dispatch is async, so the
                    # DES generates the NEXT chunk's events while the
                    # device still scans this one (flush below is the
                    # host-sync point). Controller runs can't prefetch —
                    # the next boundary may rebuild the stream.
                    n0, n1 = segments[si + 1]
                    t_pre = perf_counter() if tele else 0.0
                    with span("engine.des_prefetch", start=n0, stop=n1):
                        pending_rows = stream.take(n1 - n0)
                    if tele:
                        overlap = perf_counter() - t_pre
            else:
                with span("engine.stage", start=r0, stop=r1):
                    staged = _stack_chunk(batch_fn, r0, C)
                extra = ((jnp.asarray(start_masks[i:i + C]),)
                         if mode == "async" else ())
                t_disp = perf_counter() if tele else 0.0
                with span("engine.dispatch", start=r0, stop=r1):
                    params, state, mets = chunk_jit(
                        params, state, staged, *extra,
                        jnp.asarray(masks[i:i + C]), keys[i:i + C])
            if tele:
                jax.block_until_ready(mets)
                t_sync = perf_counter()
                telemetry.emit(RoundTelemetry(
                    r0, r1, "measured", mode,
                    np.full(C, (t_sync - t_disp) / C),
                    staging_seconds=t_disp - t_host,
                    staging_bytes=_tree_nbytes(staged),
                    dispatch_seconds=t_sync - t_disp,
                    overlap_seconds=overlap))
            with span("engine.flush", start=r0, stop=r1):
                flush(mets, r0, r1)
            if (checkpointer is not None and ckpt_every
                    and r1 % ckpt_every == 0 and r1 < rounds):
                checkpointer.save(r1 - 1, _ckpt_tree(params, state),
                                  metadata=ckpt_meta())

    def _cat(k2):
        arrs = [c[k2] for c in chunks]
        shapes = {a.shape[1:] for a in arrs}
        if len(shapes) > 1:     # controller changed τ: pad trailing axes
            full = tuple(max(dims) for dims in zip(*shapes))
            arrs = [np.pad(a, [(0, 0)] + [(0, t - s) for s, t
                                          in zip(a.shape[1:], full)])
                    for a in arrs]
        return np.concatenate(arrs)

    metrics = {k2: _cat(k2) for k2 in chunks[0]}
    loss = metrics["loss"]
    round_loss = ((loss * masks).sum(1)
                  / np.maximum(masks.sum(1), 1.0)).astype(np.float64)
    if checkpointer is not None:
        checkpointer.save(rounds - 1, _ckpt_tree(params, state),
                          metadata=ckpt_meta(loss=float(round_loss[-1])),
                          block=True)
    return EngineResult(params, state, metrics, round_loss,
                        round_times, float(round_times.sum()), tau_used)
