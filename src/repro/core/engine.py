"""Unified algorithm engine: one driver, every algorithm, rounds fused
on-device.

The paper's headline claim is wall-clock (rounds over *time*), yet the
historical drivers executed rounds one Python iteration at a time — each
paying a dispatch, a host sync, and an un-donated parameter copy per round,
and each hand-rolling its own loop + algorithm special cases. This module
replaces all of them:

  Algorithm    protocol (init_state / round_fn / time_model / metrics_spec)
               with registered adapters for mu_splitfed, vanilla, gas,
               fedavg, and fedlora — every algorithm is a pure
               (params, state, batch, mask, key) -> (params, state, metrics)
               round, so the driver is algorithm-agnostic (GAS state
               threading included).
  run_rounds   the driver. mode='scan' (default) lifts the loop into a
               chunked, jit'd jax.lax.scan over rounds with params/state
               DONATED across chunks: straggler delays, participation /
               deadline masks (straggler.make_schedule) and per-round
               fold-in keys are precomputed on host as stacked (R, M) /
               (R, 2) arrays and scanned as data; metrics are stacked per
               chunk and flushed to host only at chunk boundaries — which
               is also where checkpointing hooks in. mode='python' keeps
               the legacy one-jit-call-per-round loop as the equivalence
               baseline (benchmarks/bench_rounds.py gates scan == python
               on the loss trajectory; perf ladder rung v5).

Chunk boundaries are aligned to ckpt_every, so a run killed after chunk k
resumes from its checkpoint onto the *same* round boundaries — with
stateless data order and precomputed schedules the resumed trajectory is
bit-identical to an uninterrupted run (tests/test_engine.py).
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, NamedTuple, Optional, Protocol,
                    Tuple, Union, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import straggler as strag
from repro.core.baselines import (fedavg_round, fedlora_round, gas_init_state,
                                  gas_round, vanilla_splitfed_round)
from repro.core.splitfed import mu_splitfed_round

Params = Any
State = Any
Batch = Dict[str, Any]
MetricsDict = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# the Algorithm protocol + registry
# ---------------------------------------------------------------------------

@runtime_checkable
class Algorithm(Protocol):
    """One federated algorithm as the engine sees it.

    round_fn must be pure/jit-able; all system effects (delays, staleness,
    participation) enter as the (M,) mask data row. State is an arbitrary
    pytree carried across rounds (empty tuple for stateless algorithms).
    """
    name: str

    def init_state(self, cfg: ModelConfig, sfl: SFLConfig, params: Params,
                   batch0: Batch) -> State: ...

    def round_fn(self, cfg: ModelConfig, sfl: SFLConfig, params: Params,
                 state: State, batch: Batch, mask: jax.Array, key: jax.Array
                 ) -> Tuple[Params, State, MetricsDict]: ...

    def time_model(self, delays: np.ndarray, mask: np.ndarray,
                   sfl: SFLConfig, sched: strag.Schedule) -> float: ...

    def metrics_spec(self, cfg: ModelConfig, sfl: SFLConfig
                     ) -> Dict[str, Tuple[int, ...]]: ...


ALGORITHMS: Dict[str, Callable[..., Algorithm]] = {}


def register(cls):
    ALGORITHMS[cls.name] = cls
    return cls


def get_algorithm(name: Union[str, Algorithm], **opts) -> Algorithm:
    """Resolve an algorithm by registry name (instantiating it with
    ``opts``) or pass a ready-made Algorithm instance through."""
    if isinstance(name, str):
        if name not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {name!r}; "
                             f"registered: {sorted(ALGORITHMS)}")
        return ALGORITHMS[name](**opts)
    if opts:
        raise ValueError("opts only apply when resolving by name")
    return name


class AlgorithmBase:
    """Shared defaults: stateless, standard mask row, per-client loss."""

    def init_state(self, cfg, sfl, params, batch0) -> State:
        return ()

    def round_mask(self, sched: strag.Schedule, r: int) -> np.ndarray:
        """The (M,) mask row round r's round_fn consumes (GAS overrides
        with its freshness rule)."""
        return sched.masks[r % sched.n_rounds]

    def metrics_spec(self, cfg, sfl) -> Dict[str, Tuple[int, ...]]:
        return {"loss": (sfl.n_clients,)}


@register
class MuSplitFed(AlgorithmBase):
    """The paper's τ-unbalanced split federated round (Algorithm 1)."""
    name = "mu_splitfed"

    def __init__(self, client_mode: str = "parallel",
                 aggregation: str = "dense", replay: str = "auto",
                 eval_loss: bool = True):
        self.client_mode = client_mode
        self.aggregation = aggregation
        self.replay = replay
        self.eval_loss = eval_loss

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, m = mu_splitfed_round(
            cfg, sfl, params, batch, mask, key, client_mode=self.client_mode,
            aggregation=self.aggregation, replay=self.replay,
            eval_loss=self.eval_loss)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_mu_splitfed(delays, mask, sched.t_server,
                                            sfl.tau, sched.t_comm)

    def metrics_spec(self, cfg, sfl):
        M = sfl.n_clients
        return {"loss": (M,), "server_deltas": (M, sfl.tau),
                "client_delta": (M,)}


@register
class VanillaSplitFed(MuSplitFed):
    """SplitFed without unbalanced updates — exactly MU-SplitFed at τ=1."""
    name = "vanilla"

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, m = vanilla_splitfed_round(
            cfg, sfl, params, batch, mask, key, client_mode=self.client_mode,
            aggregation=self.aggregation, replay=self.replay,
            eval_loss=self.eval_loss)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_vanilla(delays, mask, sched.t_server,
                                        sched.t_comm)

    def metrics_spec(self, cfg, sfl):
        return {"loss": (sfl.n_clients,), "server_deltas": (sfl.n_clients, 1),
                "client_delta": (sfl.n_clients,)}


@register
class Gas(AlgorithmBase):
    """GAS-like async SFL with a carried activation buffer. ``fresh``
    selects where the freshness mask comes from: 'mask' (the schedule's
    participation·deadline row — the training driver's convention) or
    'median' (clients at/below the per-round median delay — Fig. 2)."""
    name = "gas"

    def __init__(self, aggregation: str = "dense", replay: str = "auto",
                 fresh: str = "mask"):
        if fresh not in ("mask", "median"):
            raise ValueError(f"gas: fresh must be 'mask'|'median', "
                             f"got {fresh!r}")
        self.aggregation = aggregation
        self.replay = replay
        self.fresh = fresh

    def init_state(self, cfg, sfl, params, batch0):
        return gas_init_state(cfg, sfl, params, batch0)

    def round_mask(self, sched, r):
        i = r % sched.n_rounds
        return (sched.fresh_median[i] if self.fresh == "median"
                else sched.masks[i])

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        params, state, m = gas_round(cfg, sfl, params, state, batch, mask,
                                     key, aggregation=self.aggregation,
                                     replay=self.replay)
        return params, state, {"loss": m.loss, "server_deltas": m.server_deltas,
                               "client_delta": m.client_delta}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_gas(delays, mask, sched.t_server, sched.t_gen,
                                    sched.t_comm)

    def metrics_spec(self, cfg, sfl):
        return {"loss": (sfl.n_clients,), "server_deltas": (sfl.n_clients, 1),
                "client_delta": (sfl.n_clients,)}


@register
class FedAvg(AlgorithmBase):
    """First-order FedAvg (full model on every client, E local steps)."""
    name = "fedavg"

    def __init__(self, lr: Optional[float] = None, local_steps: int = 1,
                 optimizer: str = "sgd"):
        self.lr = lr
        self.local_steps = local_steps
        self.optimizer = optimizer

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        from repro.models import loss_fn
        first = (jax.tree.map(lambda a: a[:, 0], batch)
                 if self.local_steps > 1 else batch)
        loss0 = jax.vmap(lambda b: loss_fn(cfg, params, b))(first)
        params = fedavg_round(cfg, params, batch, mask,
                              self.lr if self.lr is not None else sfl.lr_client,
                              self.local_steps, self.optimizer,
                              eta_g=sfl.lr_global)
        return params, state, {"loss": loss0.astype(jnp.float32)}

    def time_model(self, delays, mask, sfl, sched):
        return strag.round_time_local_only(delays, mask, sched.t_comm)


@register
class FedLora(FedAvg):
    """FedAvg over LoRA adapters only; the base params never move — the
    adapter tree is the engine state."""
    name = "fedlora"

    def __init__(self, rank: int = 4, alpha: float = 16.0,
                 lr: Optional[float] = None):
        super().__init__(lr=lr)
        self.rank = rank
        self.alpha = alpha

    def init_state(self, cfg, sfl, params, batch0):
        from repro.optim.lora import init_lora
        return init_lora(cfg, params, self.rank,
                         jax.random.PRNGKey(sfl.seed))

    def round_fn(self, cfg, sfl, params, state, batch, mask, key):
        from repro.models import loss_fn
        from repro.optim.lora import apply_lora
        merged = apply_lora(params, state, self.alpha)
        loss0 = jax.vmap(lambda b: loss_fn(cfg, merged, b))(batch)
        lora = fedlora_round(cfg, params, state, batch, mask,
                             self.lr if self.lr is not None else sfl.lr_client,
                             self.alpha, eta_g=sfl.lr_global)
        return params, lora, {"loss": loss0.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# the fused multi-round driver
# ---------------------------------------------------------------------------

class EngineResult(NamedTuple):
    params: Params
    state: State
    metrics: Dict[str, np.ndarray]  # per-round stacks, leading dim = rounds run
    round_loss: np.ndarray          # (rounds,) mask-weighted mean client loss
    round_times: np.ndarray         # (rounds,) simulated per-round wall-clock
    sim_time: float                 # sum(round_times)


class ChunkInfo(NamedTuple):
    """Everything a chunk_callback needs about the rounds just flushed —
    engine-computed, so drivers never re-derive losses/times/masks."""
    start: int                      # first absolute round in the chunk
    stop: int                       # one past the last round
    metrics: Dict[str, np.ndarray]  # host-flushed stacks, leading dim C
    masks: np.ndarray               # (C, M) the mask rows the rounds consumed
    round_loss: np.ndarray          # (C,) mask-weighted mean client loss
    round_times: np.ndarray         # (C,) simulated per-round wall-clock


def fold_in_keys(key, start: int, n: int) -> jax.Array:
    """(n, 2) stacked per-round keys: keys[i] = fold_in(key, start + i) —
    identical to what the legacy loops derived one round at a time."""
    return jax.vmap(lambda r: jax.random.fold_in(key, r))(
        jnp.arange(start, start + n))


def make_chunk_fn(algo: Algorithm, cfg: ModelConfig, sfl: SFLConfig):
    """The fused multi-round step: scan algo.round_fn over a chunk of
    precomputed (batches, masks, keys) rows. Shared with the perf-ladder
    cell builder (launch/steps.py train_multi)."""
    def run_chunk(params, state, batches, masks, keys):
        def body(carry, xs):
            p, s = carry
            b, m, k = xs
            p, s, met = algo.round_fn(cfg, sfl, p, s, b, m, k)
            return (p, s), met
        (params, state), mets = jax.lax.scan(body, (params, state),
                                             (batches, masks, keys))
        return params, state, mets
    return run_chunk


def _stack_chunk(batch_fn, r0: int, n: int):
    """Stack n rounds of per-client batches -> leaves (n, M, ...). Host
    (numpy) leaves stack on host then upload once; device leaves stack
    on-device — batch_fn output must never bounce device->host->device."""
    rounds = [batch_fn(r0 + i) for i in range(n)]

    def stack(*xs):
        if all(isinstance(x, np.ndarray) for x in xs):
            return jnp.asarray(np.stack(xs))
        return jnp.stack([jnp.asarray(x) for x in xs])

    return jax.tree.map(stack, *rounds)


def _copy_tree(tree):
    # donation safety: the caller keeps its own params/state buffers
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


def _cached_jit(algo: Algorithm, mode: str, cfg: ModelConfig, sfl: SFLConfig,
                build: Callable):
    """Per-algorithm-instance jit cache: repeated run_rounds calls with the
    same (algo, cfg, sfl) reuse the compiled executables instead of
    re-tracing a fresh closure every call (jax.jit caches by function
    identity, which a fresh lambda defeats)."""
    cache = getattr(algo, "_engine_jit_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(algo, "_engine_jit_cache", cache)
    k = (mode, cfg, sfl)
    if k not in cache:
        cache[k] = build()
    return cache[k]


def run_rounds(algorithm: Union[str, Algorithm], cfg: ModelConfig,
               sfl: SFLConfig, params: Params, batch_fn: Callable[[int], Batch],
               schedule: strag.Schedule, key, *, rounds: int,
               start_round: int = 0, chunk_size: int = 8,
               mode: str = "scan", state: Optional[State] = None,
               checkpointer=None, ckpt_every: int = 0,
               chunk_callback: Optional[Callable] = None,
               **algo_opts) -> EngineResult:
    """Run rounds [start_round, rounds) of ``algorithm``.

    batch_fn(r) returns the round-r host batch (leaves with leading M dim;
    must be stateless in r so restarts are exact). ``schedule`` provides the
    (R, M) delay/mask rows (cyclic if shorter than the run) and the
    wall-clock knobs. ``key`` is the run's base PRNG key; round r uses
    fold_in(key, r).

    mode='scan': rounds execute in chunks of ``chunk_size`` as one jit'd
    lax.scan per chunk with params/state donated between chunks; metrics
    flush to host (and ``chunk_callback(ChunkInfo, params, state)`` /
    checkpointing fire) only at chunk boundaries, which are aligned to
    ckpt_every. mode='python': the legacy per-round loop — one jit call +
    host sync per round (equivalence/bench baseline).

    Checkpoints save at step = round index of the last completed round in
    the chunk; resume by restoring params and passing start_round=step+1.
    """
    algo = get_algorithm(algorithm, **algo_opts)
    if mode not in ("scan", "python"):
        raise ValueError(f"run_rounds: mode must be 'scan'|'python', "
                         f"got {mode!r}")
    n_run = rounds - start_round
    if n_run <= 0:
        empty = np.zeros((0,), np.float64)
        return EngineResult(params, state, {}, empty, empty, 0.0)

    if state is None:
        state = algo.init_state(cfg, sfl, params,
                                jax.tree.map(jnp.asarray, batch_fn(start_round)))

    rows = list(range(start_round, rounds))
    mask_of = getattr(algo, "round_mask",
                      lambda sched, r: sched.masks[r % sched.n_rounds])
    masks = np.stack([mask_of(schedule, r) for r in rows])
    round_times = np.array([algo.time_model(*schedule.row(r), sfl, schedule)
                            for r in rows])
    keys = fold_in_keys(key, start_round, n_run)

    chunks: list = []

    def flush(mets, r0, r1):
        host = jax.tree.map(np.asarray, mets)      # host sync: chunk boundary
        chunks.append(host)
        if chunk_callback is not None:
            i0, i1 = r0 - start_round, r1 - start_round
            m = masks[i0:i1]
            rl = ((host["loss"] * m).sum(1)
                  / np.maximum(m.sum(1), 1.0)).astype(np.float64)
            chunk_callback(ChunkInfo(r0, r1, host, m, rl,
                                     round_times[i0:i1]), params, state)

    if mode == "python":
        round_jit = _cached_jit(algo, "python", cfg, sfl, lambda: jax.jit(
            lambda p, s, b, m, k: algo.round_fn(cfg, sfl, p, s, b, m, k)))
        for i, r in enumerate(rows):
            b = jax.tree.map(jnp.asarray, batch_fn(r))
            params, state, met = round_jit(params, state, b,
                                           jnp.asarray(masks[i]), keys[i])
            flush(jax.tree.map(lambda a: a[None], met), r, r + 1)
            if (checkpointer is not None and ckpt_every
                    and (r + 1) % ckpt_every == 0 and r + 1 < rounds):
                checkpointer.save(r, params)
    else:
        params, state = _copy_tree(params), _copy_tree(state)
        chunk_jit = _cached_jit(algo, "scan", cfg, sfl, lambda: jax.jit(
            make_chunk_fn(algo, cfg, sfl), donate_argnums=(0, 1)))
        r = start_round
        while r < rounds:
            C = min(chunk_size, rounds - r)
            if ckpt_every:
                C = min(C, ckpt_every - r % ckpt_every)
            i = r - start_round
            params, state, mets = chunk_jit(
                params, state, _stack_chunk(batch_fn, r, C),
                jnp.asarray(masks[i:i + C]), keys[i:i + C])
            r += C
            flush(mets, r - C, r)
            if (checkpointer is not None and ckpt_every
                    and r % ckpt_every == 0 and r < rounds):
                checkpointer.save(r - 1, params)

    metrics = {k: np.concatenate([c[k] for c in chunks])
               for k in chunks[0]}
    loss = metrics["loss"]
    round_loss = ((loss * masks).sum(1)
                  / np.maximum(masks.sum(1), 1.0)).astype(np.float64)
    if checkpointer is not None:
        checkpointer.save(rounds - 1, params,
                          metadata={"loss": float(round_loss[-1])}, block=True)
    return EngineResult(params, state, metrics, round_loss,
                        round_times, float(round_times.sum()))
