"""Baselines the paper compares against (§5):

  vanilla_splitfed_round : SplitFed with ZO but no unbalanced updates
                           (exactly MU-SplitFed at τ=1 — shared code path,
                           which is itself a correctness check).
  gas_round              : GAS-like asynchronous SFL — the server proceeds
                           with *stale buffered activations* for slow
                           clients instead of waiting. Staleness enters as a
                           fresh/stale mask from the wall-clock simulator;
                           an activation buffer is carried across rounds.
  fedavg_round           : first-order FedAvg (full model on every client,
                           E local AdamW/SGD steps) — the memory-comparison
                           and convergence baseline of Fig. 4 / §5.
  fedlora_round          : FedAvg + LoRA adapters (only (A,B) train/ship).

All rounds are pure jit-able functions; system effects (delays, staleness,
participation) are data inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SFLConfig
from repro.core import zo
from repro.core.splitfed import RoundMetrics, _client_round, mu_splitfed_round
from repro.models import (client_forward, loss_fn, merge_params,
                          server_forward, split_params)
from repro.optim import adamw_init, adamw_update, make_optimizer
from repro.optim.lora import apply_lora, init_lora

Params = Any


# ---------------------------------------------------------------------------
# vanilla SplitFed (τ=1, ZO)
# ---------------------------------------------------------------------------

def vanilla_splitfed_round(cfg: ModelConfig, sfl: SFLConfig, params: Params,
                           batches, active_mask, round_key, **kw):
    sfl1 = dataclasses.replace(sfl, tau=1)
    return mu_splitfed_round(cfg, sfl1, params, batches, active_mask,
                             round_key, **kw)


# ---------------------------------------------------------------------------
# GAS-like asynchronous SFL with an activation buffer
# ---------------------------------------------------------------------------

class GasState(NamedTuple):
    h_buffer: Any        # stacked (M, ...) last-seen unperturbed embeddings
    label_buffer: Any    # matching labels/batches for the stale activations


def gas_init_state(cfg: ModelConfig, sfl: SFLConfig, params: Params, batches):
    """Fill the buffer with an initial sweep (round 0 everyone is fresh)."""
    xc, _ = split_params(cfg, params, sfl.cut_units)
    h = jax.vmap(lambda b: client_forward(cfg, xc, b))(batches)
    return GasState(h_buffer=h, label_buffer=batches)


def gas_round(cfg: ModelConfig, sfl: SFLConfig, params: Params, state: GasState,
              batches, fresh_mask, round_key, *,
              aggregation: str = "dense",
              replay: str = "auto") -> Tuple[Params, GasState, RoundMetrics]:
    """fresh_mask (M,) f32: 1 = client delivered this round; 0 = straggler,
    server trains its replica from the buffered stale activation instead.
    Fresh clients also get the scalar ZO backprop; stale ones don't update
    their client side this round (they never received δ_c in time).

    aggregation='seed_replay' replays each client's server (key, coeff)
    records (and the client-side (ukey, ccoeff)) into the global halves via
    zo.fused_replay_updates instead of averaging dense replicas — the same
    compressed wire format as mu_splitfed_round."""
    if aggregation not in ("dense", "seed_replay"):
        raise ValueError(f"gas_round: unsupported aggregation "
                         f"{aggregation!r} (want 'dense' or 'seed_replay')")
    M = sfl.n_clients
    xc, xs = split_params(cfg, params, sfl.cut_units)
    mkeys = jax.vmap(lambda i: jax.random.fold_in(round_key, i))(jnp.arange(M))

    def per_client(b_new, b_old, h_old, k, fresh):
        ukey = jax.random.fold_in(k, 0)
        skey = jax.random.fold_in(k, 1)
        # fresh clients compute new messages; stale reuse the buffer
        h_new = client_forward(cfg, xc, b_new)
        h = jax.tree.map(lambda a, o: jnp.where(fresh > 0, a, o), h_new, h_old)
        b_used = jax.tree.map(lambda a, o: jnp.where(fresh > 0, a, o),
                              b_new, b_old)
        hp = client_forward(cfg, zo.perturb(xc, ukey, +sfl.zo_eps,
                                            sfl.perturbation_dist), b_new)
        hm = client_forward(cfg, zo.perturb(xc, ukey, -sfl.zo_eps,
                                            sfl.perturbation_dist), b_new)
        loss0 = server_forward(cfg, xs, h, b_used)

        def loss_of(sp):
            return server_forward(cfg, sp, h, b_used)
        sp_new, delta, (skeys, scoeffs) = zo.spsa_step(
            loss_of, xs, skey, sfl.zo_eps, sfl.lr_server,
            sfl.n_perturbations, sfl.perturbation_dist, replay=replay)
        delta_c = (server_forward(cfg, sp_new, hp, b_new)
                   - server_forward(cfg, sp_new, hm, b_new)).astype(jnp.float32)
        ccoeff = fresh * sfl.lr_client * delta_c / (2.0 * sfl.zo_eps)
        return {"xs_final": sp_new, "h": h, "b": b_used, "ukey": ukey,
                "ccoeff": ccoeff, "loss0": loss0, "delta": delta,
                "skeys": skeys, "scoeffs": scoeffs}

    out = jax.vmap(per_client)(batches, state.label_buffer, state.h_buffer,
                               mkeys, fresh_mask)
    w = jnp.full((M,), 1.0 / M, jnp.float32)

    if aggregation == "dense":
        def agg(g, stacked):
            d = jnp.tensordot(w, (stacked - g[None]).astype(jnp.float32),
                              axes=1)
            return (g + sfl.lr_global * d).astype(g.dtype)
        xs_new = jax.tree.map(agg, xs, out["xs_final"])
    else:  # seed_replay: flatten the (M, P) server records, weight by η_g·w_m
        xs_new = zo.replay_weighted_records(
            xs, out["skeys"], out["scoeffs"], sfl.lr_global * w,
            sfl.perturbation_dist, impl=replay)
    xc_new = zo.replay_weighted_records(
        xc, out["ukey"], out["ccoeff"], sfl.lr_global * w,
        sfl.perturbation_dist, impl=replay)
    new_state = GasState(h_buffer=out["h"], label_buffer=out["b"])
    metrics = RoundMetrics(loss=out["loss0"],
                           server_deltas=out["delta"][:, None],
                           client_delta=out["ccoeff"])
    return merge_params(cfg, xc_new, xs_new), new_state, metrics


# ---------------------------------------------------------------------------
# FedAvg (first-order, full model on clients)
# ---------------------------------------------------------------------------

def fedavg_round(cfg: ModelConfig, params: Params, batches, active_mask,
                 lr: float, local_steps: int = 1, optimizer: str = "sgd",
                 eta_g: float = 1.0):
    """One FedAvg round: E local FO steps per client (vmapped), FedAvg agg.
    Local batches: leaves (M, E, b, S) when local_steps > 1 else (M, b, S)."""
    M = active_mask.shape[0]
    init_opt, update = make_optimizer(optimizer)
    grad_fn = jax.grad(lambda p, b: loss_fn(cfg, p, b))

    def local(b):
        def step(carry, bi):
            p, s = carry
            g = grad_fn(p, bi)
            p, s = update(p, g, s, lr)
            return (p, s), None
        bs = (jax.tree.map(lambda a: a[None], b) if local_steps == 1
              else b)
        (p_f, _), _ = jax.lax.scan(step, (params, init_opt(params)), bs)
        return p_f

    stacked = jax.vmap(local)(batches)
    wsum = jnp.maximum(jnp.sum(active_mask), 1.0)
    w = (active_mask / wsum).astype(jnp.float32)

    def agg(g, st):
        d = jnp.tensordot(w, (st - g[None]).astype(jnp.float32), axes=1)
        return (g + eta_g * d).astype(g.dtype)
    return jax.tree.map(agg, params, stacked)


# ---------------------------------------------------------------------------
# FedAvg + LoRA
# ---------------------------------------------------------------------------

def fedlora_round(cfg: ModelConfig, params: Params, lora, batches,
                  active_mask, lr: float, alpha: float = 16.0,
                  eta_g: float = 1.0):
    """Clients train only the LoRA adapters; only (A,B) are aggregated."""
    grad_fn = jax.grad(
        lambda lo, b: loss_fn(cfg, apply_lora(params, lo, alpha), b))

    def local(b):
        g = grad_fn(lora, b)
        return jax.tree.map(lambda x, gg: (x.astype(jnp.float32)
                                           - lr * gg.astype(jnp.float32)
                                           ).astype(x.dtype), lora, g)

    stacked = jax.vmap(local)(batches)
    wsum = jnp.maximum(jnp.sum(active_mask), 1.0)
    w = (active_mask / wsum).astype(jnp.float32)

    def agg(g, st):
        d = jnp.tensordot(w, (st - g[None]).astype(jnp.float32), axes=1)
        return (g + eta_g * d).astype(g.dtype)
    return jax.tree.map(agg, lora, stacked)
