"""Theory calculators: convergence bounds (Thm 4.1 / 4.3, Cor 4.2 / 4.4),
the Table-2 communication-complexity comparison, and the cut-layer planner
(d_c = √(d/τ)) that couples the split point to the unbalanced-update ratio.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Thm 4.1 (MU-Split, M=1) — Eq. (8)
# ---------------------------------------------------------------------------

def mu_split_bound(F0: float, L: float, T: int, tau: int, d_c: int, d_s: int,
                   sigma_c: float, sigma_s: float, lam: float,
                   eta: float | None = None) -> Dict[str, float]:
    """Evaluate the five terms of Eq. (8). Returns each term + total."""
    if eta is None:
        eta = min(1.0 / (64 * L * (tau + 2 * d_s)), 1.0 / (16 * L * tau * d_c))
    t1 = 4 * F0 / (eta * tau * T)
    t2 = 16 * eta * L * (eta * tau * L + 1) * d_s * sigma_s ** 2
    t3 = 8 * eta * tau * L * d_c * sigma_c ** 2
    t4 = 4 * L ** 2 * (eta ** 2 * tau ** 2 * L ** 2 + 0.25) * lam ** 2 * d_s ** 3
    t5 = L ** 2 * lam ** 2 * d_c ** 3
    return {"opt": t1, "var_s": t2, "var_c": t3, "zo_s": t4, "zo_c": t5,
            "total": t1 + t2 + t3 + t4 + t5, "eta": eta}


def mu_split_rate(F0: float, L: float, T: int, tau: int, d: int,
                  sigma_c: float, sigma_s: float) -> float:
    """Cor 4.2 — the O(√(d/(τT))) rate with the optimal cut d_c = √(d/τ)."""
    sd, st = math.sqrt(d), math.sqrt(tau * T)
    return (4 * sd * F0 / st + 48 * L * sd * sigma_s ** 2 / st
            + 9 * sd / st + 8 * L * sigma_c ** 2 / math.sqrt(T))


# ---------------------------------------------------------------------------
# Thm 4.3 (MU-SplitFed, M clients) — Eq. (10) / Cor 4.4 — Eq. (11)
# ---------------------------------------------------------------------------

def mu_splitfed_bound(F0: float, L: float, T: int, tau: int, M: int,
                      d_c: int, d_s: int, sigma_c: float, sigma_s: float,
                      eps_het: float, lam: float, eta: float | None = None,
                      eta_g: float | None = None) -> Dict[str, float]:
    """Evaluate the seven terms of Eq. (10)."""
    if eta is None:
        eta = min(1.0 / (120 * L * tau * (1 + 2 * d_s / tau)),
                  M / (12 * tau * L * d_c))
    if eta_g is None:
        eta_g = math.sqrt(tau * M)
    t1 = 4 * F0 / (T * eta_g * eta * tau)
    t2 = 16 * eta * (2 * eta * tau * L + eta_g / M) * L * d_s * sigma_s ** 2
    t3 = 4 * eta_g * eta * tau * L * d_c * sigma_c ** 2 / M
    t4 = 24 * eta * (4 * eta * tau * L + eta_g / M) * L * (tau + 2 * d_s) * eps_het ** 2
    t5 = 12 * eta_g * eta * tau * L * d_c * eps_het ** 2 / M
    t6 = (1 / tau + 8 * eta ** 2 * tau * L ** 2
          + 2 * eta_g * eta / M) * tau * L ** 2 * lam ** 2 * d_s ** 3
    t7 = L ** 2 * lam ** 2 * d_c ** 3
    return {"opt": t1, "var_s": t2, "var_c": t3, "het_s": t4, "het_c": t5,
            "zo_s": t6, "zo_c": t7, "total": sum((t1, t2, t3, t4, t5, t6, t7)),
            "eta": eta, "eta_g": eta_g}


def mu_splitfed_rate(F0: float, L: float, T: int, tau: int, M: int, d: int,
                     sigma_c: float, sigma_s: float, eps_het: float) -> float:
    """Cor 4.4 — the O(√(d/(τTM))) rate."""
    sd = math.sqrt(d)
    stm = math.sqrt(tau * T * M)
    return (4 * L * sd * F0 / stm
            + 8 * sd * (3 * eps_het ** 2 + 2 * sigma_s ** 2) / stm
            + 32 * sd * (3 * eps_het ** 2 + sigma_s ** 2) / (tau * T)
            + (12 * eps_het ** 2 + 4 * sigma_c ** 2) / math.sqrt(T * M)
            + 6 * sd / (tau * T))


# ---------------------------------------------------------------------------
# Table 2: communication complexity to reach epsilon accuracy
# ---------------------------------------------------------------------------

def comm_complexity(method: str, d: int, tau: int, M: int, K: int,
                    eps: float) -> float:
    """Split-Server communication cost (number of scalar rounds, up to
    constants) to reach an ε-approximate stationary point."""
    e2 = eps ** 2
    table = {
        "sfl_v1": K / e2,
        "sfl_v2": K / (M * e2),
        "mu_splitfed_tau1": d / (M * e2),
        "mu_splitfed": d / (tau * M * e2),
        "mu_splitfed_tau_to_d": 1.0 / (M * e2),
    }
    return table[method]


def rounds_to_eps(d: int, tau: int, M: int, eps: float) -> float:
    """T needed so that √(d/(τTM)) <= ε  =>  T = d/(τ M ε²)."""
    return d / (tau * M * eps ** 2)


# ---------------------------------------------------------------------------
# cut-layer planner (Cor 4.2/4.4: d_c = √(d/τ))
# ---------------------------------------------------------------------------

def optimal_dc(d: int, tau: int) -> float:
    return math.sqrt(d / tau)


def optimal_tau_for_cut(d: int, d_c: int, tau_max: int = 64) -> int:
    """Invert d_c = √(d/τ): τ* = d/d_c² (clipped to [1, tau_max])."""
    tau = d / max(d_c, 1) ** 2
    return int(min(max(round(tau), 1), tau_max))


def plan_cut(cfg: ModelConfig, tau: int) -> Tuple[int, Dict[int, float]]:
    """Choose the unit-boundary cut whose d_c best matches √(d/τ).

    Returns (cut_units, {cut: |log(d_c/target)|}). Uses exact per-cut
    parameter counts from the model's split machinery.
    """
    from repro.models import split_dims
    n_cuts = (cfg.n_encoder_layers if cfg.is_encoder_decoder else cfg.n_units)
    scores: Dict[int, float] = {}
    best, best_score = 1, float("inf")
    for cut in range(1, n_cuts + 1):
        d_c, d_s = split_dims(cfg, cut)
        d = d_c + d_s
        target = optimal_dc(d, tau)
        score = abs(math.log(max(d_c, 1) / target))
        scores[cut] = score
        if score < best_score:
            best, best_score = cut, score
    return best, scores
