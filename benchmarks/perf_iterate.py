import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf-iteration driver (§Perf): lower+compile ONE cell under a named
optimization variant and print its roofline terms — the measure step of the
hypothesis → change → measure → validate loop.

Variants (cumulative ladder):
  v0  paper-faithful baseline      (recorded in dryrun_*.json, pre-ladder)
  v1  + f32-accum CE dot + banded SWA (exact-math rewrites, always on now)
  v2  + counter-based ZO noise     (murmur3+Box-Muller; = TPU kernel stream)
  v3  + seed-replay aggregation    (O(Mτ) scalars across the slow axis;
                                    records applied via an N-step scan —
                                    N = Mτ P full parameter HBM sweeps)
  v4  + fused batched replay       (zo.fused_replay_updates: all N record
                                    contributions accumulated per leaf in
                                    one pass — one HBM read + one write per
                                    parameter regardless of N)
  v5  + fused multi-round scan     (engine chunk: C global rounds in ONE
                                    dispatch — lax.scan over rounds with
                                    donated params, schedule rows as data;
                                    host syncs once per chunk instead of
                                    per round. Host-overhead numbers:
                                    benchmarks/bench_rounds.py)

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --arch qwen3-14b --shape train_4k --variant v5 [--multi-pod]
"""
import argparse
import dataclasses
import json
import time

from repro.configs import SHAPES_BY_NAME
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_cell, build_train_multi_cell,
                                default_sfl, lower_cell)
from repro.configs import get_config

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False, tau: int = 2,
                rounds_per_chunk: int = 4) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch)
    sfl = default_sfl(cfg, tau=tau)
    aggregation = "dense"
    replay = "scan"
    if variant >= "v2" and shape.kind == "train":
        sfl = dataclasses.replace(sfl, perturbation_dist="counter")
    if variant >= "v3" and shape.kind == "train":
        aggregation = "seed_replay"
    if variant >= "v4" and shape.kind == "train":
        replay = "fused"
    t0 = time.time()
    if variant >= "v5" and shape.kind == "train":
        cell = build_train_multi_cell(arch, shape, mesh, sfl=sfl,
                                      rounds_per_chunk=rounds_per_chunk,
                                      aggregation=aggregation, replay=replay,
                                      tau=tau)
    else:
        cell = build_cell(arch, shape, mesh, sfl=sfl if shape.kind == "train"
                          else None, aggregation=aggregation, replay=replay,
                          tau=tau)
    compiled = lower_cell(cell).compile()
    a = analyze_compiled(compiled)
    # v5 lowers C rounds per dispatch: normalize per ROUND so rows stay
    # comparable across ladder rungs
    per_round = (rounds_per_chunk if variant >= "v5"
                 and shape.kind == "train" else 1)
    for k in ("expanded_dot_flops", "expanded_hbm_bytes", "total_bytes"):
        a[k] = a[k] / per_round
    a["bytes_by_kind"] = {k: v / per_round
                          for k, v in a["bytes_by_kind"].items()}
    t_c = a["expanded_dot_flops"] / PEAK_FLOPS
    t_m = a["expanded_hbm_bytes"] / 2.0 / HBM_BW
    t_x = a["total_bytes"] / LINK_BW
    mem = compiled.memory_analysis()
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "rounds_per_chunk": per_round,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": max((("compute", t_c), ("memory", t_m),
                         ("collective", t_x)), key=lambda kv: kv[1])[0],
        "flops_per_chip": a["expanded_dot_flops"],
        "hbm_bytes_per_chip": a["expanded_hbm_bytes"] / 2.0,
        "coll_bytes_per_chip": a["total_bytes"],
        "coll_by_kind": a["bytes_by_kind"],
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "compile_s": round(time.time() - t0, 1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="v1")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--out", default="perf_iterations.json")
    args = ap.parse_args(argv)
    r = run_variant(args.arch, args.shape, args.variant, args.multi_pod,
                    args.tau)
    print(json.dumps({k: v for k, v in r.items() if k != "coll_by_kind"},
                     indent=1))
    print("coll_by_kind:", {k: f"{v/2**30:.1f}GiB"
                            for k, v in r["coll_by_kind"].items()})
    rows = []
    if os.path.exists(args.out):
        rows = json.load(open(args.out))
    rows.append(r)
    json.dump(rows, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
