"""Benchmark aggregator: one entry per paper table/figure + kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp


def _timed(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_microbench(rows):
    from repro.kernels.ops import rmsnorm_op, zo_update_leaf
    from repro.kernels import ref
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 1024), jnp.float32)
    s = jnp.ones((1024,), jnp.float32)
    us = _timed(jax.jit(lambda a: ref.rmsnorm_ref(a, s)), x)
    rows.append(("kernel.rmsnorm.ref_jnp", us, "oracle path"))
    us = _timed(jax.jit(lambda a: rmsnorm_op(a, s, interpret=True)), x)
    rows.append(("kernel.rmsnorm.pallas_interpret", us,
                 "correctness path (CPU interpret; perf target is TPU)"))
    us = _timed(jax.jit(lambda a: ref.zo_update_ref(a, 3, 0.1)), x)
    rows.append(("kernel.zo_update.ref_jnp", us, "oracle path"))


def round_bench(rows, rounds=3):
    from benchmarks.common import make_setup, run_mu_splitfed
    cfg, params, ds, parts, key = make_setup(M=2, batch=1, seq=32)
    t0 = time.perf_counter()
    losses = run_mu_splitfed(cfg, params, ds, parts, key, M=2, tau=2, cut=1,
                             rounds=rounds)
    us = (time.perf_counter() - t0) / rounds * 1e6
    rows.append(("mu_splitfed.round.tiny", us,
                 f"loss {losses[0]:.3f}->{losses[-1]:.3f}"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="microbench + short paper tables only")
    ap.add_argument("--rounds", type=int, default=0,
                    help="override rounds for the training benchmarks")
    args = ap.parse_args(argv)
    rows = []

    kernel_microbench(rows)
    round_bench(rows)

    r = args.rounds or (12 if args.quick else 30)

    from benchmarks import (fig2_straggler, fig3_cutlayer_tau, fig4_memory,
                            table1_tau_accuracy, table2_comm_complexity)
    t0 = time.perf_counter()
    t1 = table1_tau_accuracy.run(rounds=r)
    rows.append(("paper.table1.tau_sweep", (time.perf_counter() - t0) * 1e6,
                 " ".join(f"tau{k}={v['final_loss']:.3f}"
                          for k, v in t1.items())))

    t0 = time.perf_counter()
    f2 = fig2_straggler.run(rounds=r)
    best = min(f2, key=lambda a: f2[a]["loss"][-1])
    rows.append(("paper.fig2.straggler", (time.perf_counter() - t0) * 1e6,
                 " ".join(f"{a}:t={c['wall'][-1]:.0f},l={c['loss'][-1]:.3f}"
                          for a, c in f2.items())
                 + f" best_loss={best}"))

    e12 = fig2_straggler.verify_eq12()
    spread = max(x["t_mu_over_T0_tserver"] for x in e12) / max(
        min(x["t_mu_over_T0_tserver"] for x in e12), 1e-9)
    rows.append(("paper.eq12.straggler_independence", 0.0,
                 f"total_time/(T0*t_server) spread x{spread:.2f} across "
                 f"8x delay range (1.0 = perfectly independent)"))

    t0 = time.perf_counter()
    f3 = fig3_cutlayer_tau.run(rounds=max(r, 20))
    rows.append(("paper.fig3.cut_x_tau", (time.perf_counter() - t0) * 1e6,
                 "final_loss " + " ".join(f"{k}={v['final_loss']:.4f}"
                                          for k, v in f3["grid"].items())))

    t0 = time.perf_counter()
    a = fig4_memory.analytic()
    m = fig4_memory.measured_smoke()
    rows.append(("paper.fig4.client_memory", (time.perf_counter() - t0) * 1e6,
                 f"fedavg={a['fedavg_gib']:.2f}GiB "
                 f"fedlora={a['fedlora_gib']:.2f}GiB "
                 f"mu={a['mu_splitfed_client_gib']:.2f}GiB "
                 f"(paper: 8.02/5.64/1.05) measured_ratio=x{m['ratio']:.1f}"))

    th = table2_comm_complexity.theory_table()
    meas = table2_comm_complexity.measured_protocol()
    rows.append(("paper.table2.comm_complexity", 0.0,
                 f"tau_speedup={th['mu_splitfed_tau1']/th['mu_splitfed']:.1f}x"
                 f" replay_compression={meas['compression_ratio']:.0f}x"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
