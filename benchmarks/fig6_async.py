"""Fig. 6 (beyond-paper): event-driven semi-async vs the synchronous
barrier on a tiered population with correlated (tier-wide) Markov bursts.

The paper's unbalanced update (τ server steps inside the straggler wait)
softens the round barrier; the event subsystem (core/events.py) removes
it: the server commits a version as soon as a quorum of K contributions
has arrived, and stragglers fold into a later commit staleness-discounted
through the fused seed-replay path. This benchmark measures what that
buys on the regime both knobs target — a fast tier plus a much slower
tier whose availability is ONE shared Markov chain (the whole tier drops
and recovers together, availability='markov-shared'):

  sync arms     mu_splitfed, mode='scan', static τ ∈ {1, 2, 4, 8} — every
                commit waits for the slowest active client.
  semi-async    async_mu_splitfed, mode='async', quorum K < M, staleness
                discount 0.5, same τ grid — commits pace at the K-th
                arrival (the fast tier), the slow tier's work lands late
                but weighted, never dropped.

Every arm sees the same schedule draw; reported per arm: loss curve,
simulated wall-clock to the target loss (the best SYNC arm's achieved
final loss — so the question is "how much sooner does semi-async get to
where the best barrier config ends up"), and commit statistics. Rows land
in perf_iterations.json as rung v6.

    PYTHONPATH=src python -m benchmarks.fig6_async [--rounds 60]
    PYTHONPATH=src python -m benchmarks.fig6_async --smoke   # CI gate:
        mode='async' at full quorum == mode='scan', bit for bit
    PYTHONPATH=src python -m benchmarks.fig6_async --clients 4096
        # fleet-scale arm (K=64), sparse timeline only — the regime where
        # the dense path's O(V·M) rows and M-wide client vmap are the wall
"""
from __future__ import annotations

import argparse
import json
import os
import time
import tracemalloc

import jax
import numpy as np

from benchmarks.common import (make_setup, run_mu_splitfed_result, tiny_cfg,
                               wall_to_target)
from repro.core.population import ClientPopulation, Cohort, DelayModel

T_SERVER = 0.25
LR_SERVER = 5e-3           # shared flat η_s across arms (as in fig5): arms
LR_CLIENT = 1e-3           # differ only in how the system schedules the
CUT = 1                    # same-size steps
QUORUM = 6                 # K of M=8: commits pace at the fast tier
DISCOUNT = 0.5             # stale contributions halve per missed commit
TAUS = (1, 2, 4, 8)

# 6 fast clients plus a 2-client tier ~13× slower whose availability is a
# single shared Markov chain — rack-level outages: the whole tier vanishes
# for bursts of ~4 rounds and returns for ~8. The regime where the sync
# barrier pays 4-5 s/round whenever the slow tier is up, while quorum
# commits keep pacing at the fast tier and fold the slow work in stale.
POPULATION = ClientPopulation(cohorts=(
    Cohort(name="fast", n=6, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=2, delay=DelayModel(base=4.0, scale=0.5),
           availability="markov-shared", p_dropout=0.12, p_recover=0.25),
))
M = POPULATION.n_clients


def _arm(cfg, params, ds, parts, key, *, tau, rounds, seed, mode="scan",
         **kw):
    res = run_mu_splitfed_result(
        cfg, params, ds, parts, key, M=M, tau=tau, cut=CUT, rounds=rounds,
        lr_server=LR_SERVER, lr_client=LR_CLIENT, lr_global=1.0,
        population=POPULATION, t_server=T_SERVER, seed=seed, chunk_size=4,
        mode=mode, **kw)
    return {
        "loss": [float(x) for x in res.round_loss],
        "round_times": [float(x) for x in res.round_times],
        "total_time": float(res.sim_time),
        "final_loss": float(np.mean(res.round_loss[-3:])),
    }


def run(rounds=60, seed=0):
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    arms = {}
    for tau in TAUS:
        arms[f"sync_tau{tau}"] = _arm(cfg, params, ds, parts, key, tau=tau,
                                      rounds=rounds, seed=seed)
    # semi-async arms run 3x the versions: commits are cheap, and the
    # comparison metric is simulated TIME to target, not version count
    for tau in TAUS:
        arms[f"async_k{QUORUM}_tau{tau}"] = _arm(
            cfg, params, ds, parts, key, tau=tau, rounds=3 * rounds,
            seed=seed, mode="async", algorithm="async_mu_splitfed",
            aggregation="seed_replay", quorum=QUORUM,
            staleness_discount=DISCOUNT)

    # target: the best SYNC arm's achieved (smoothed) final loss — at least
    # one sync arm reaches it by construction, and the question becomes
    # "how much sooner in simulated wall-clock does semi-async get there"
    target = float(min(a["final_loss"] for n, a in arms.items()
                       if n.startswith("sync")))
    for a in arms.values():
        a["wall_to_target"] = wall_to_target(a["loss"], a["round_times"],
                                             target)
    return {"target_loss": target, "t_server": T_SERVER, "quorum": QUORUM,
            "staleness_discount": DISCOUNT,
            "population": POPULATION.describe(), "arms": arms}


def clients_arm(M_big=4096, quorum=64, versions=6, seed=0,
                timeline="sparse"):
    """Fleet-scale arm: the semi-async engine at M=4096, K=64 — sparse
    timeline only. This is the regime the sparse backend exists for: the
    dense path would materialize (V, M) timeline rows host-side AND
    dispatch an M-wide client vmap per version (device batches and client
    outputs scale with the fleet, not with the K that commits), so it is
    refused here with the estimate rather than run."""
    from repro.configs import SFLConfig
    from repro.core import engine, events
    from repro.core import straggler as strag
    from repro.models import init_params, untie_params

    n_slow = M_big // 5
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=M_big - n_slow,
               delay=DelayModel(base=0.3, scale=0.3)),
        Cohort(name="slow", n=n_slow,
               delay=DelayModel(base=4.0, scale=0.5)),
    ))
    sfl = SFLConfig(n_clients=M_big, tau=2, cut_units=CUT,
                    lr_server=LR_SERVER, lr_client=LR_CLIENT, lr_global=1.0,
                    population=pop, quorum=quorum,
                    staleness_discount=DISCOUNT, timeline="sparse")
    k_max, cap = events.resolve_store_geometry(sfl)
    if timeline != "sparse":
        raise SystemExit(
            f"--clients {M_big} requires --timeline sparse: the dense path "
            f"precompiles (V, M) rows ({M_big * 16 / 2**10:.0f} KB of host "
            f"rows per version at M={M_big}, plus the O(E) event list) and "
            f"dispatches an {M_big}-wide client vmap per version — device "
            f"batches and outputs scale with the fleet. The sparse engine "
            f"touches only k_max={k_max} starts and a {cap}-slot ring")

    cfg = tiny_cfg()
    key = jax.random.PRNGKey(seed)
    params = untie_params(cfg, init_params(cfg, key))

    def batch_fn(r):
        # fleet-size synthetic tokens, (M, b, S) host-side; the sparse
        # chunk gathers only the <= k_max started rows before dispatch
        rr = np.random.default_rng((seed << 20) + r)
        toks = rr.integers(0, cfg.vocab_size, (M_big, 2, 17), np.int32)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    sched = strag.make_schedule(seed, 8, population=pop,
                                t_server=T_SERVER, t_comm=0.05)
    t0 = time.perf_counter()
    res = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=versions, chunk_size=3,
                            mode="async", aggregation="seed_replay")
    wall = time.perf_counter() - t0

    # the host-side half of the wall, measured: dense (V, M) compile peak
    # vs the stream at the same scale
    def _peak(fn):
        tracemalloc.start()
        fn()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return peak
    d_peak = _peak(lambda: events.compile_timeline(
        sched, versions, quorum=quorum, discount=DISCOUNT, tau=2))
    st = events.TimelineStream(sched, versions, quorum=quorum,
                               discount=DISCOUNT, taus=2, k_max=k_max,
                               capacity=cap)
    s_peak = _peak(lambda: [st.take(3) for _ in range(versions // 3)])

    out = {
        "clients": M_big, "quorum": quorum, "k_max": k_max,
        "ring_capacity": cap, "versions": versions,
        "final_loss": float(np.mean(res.round_loss[-3:])),
        "sim_time": float(res.sim_time), "wall_s": round(wall, 1),
        "host_timeline_peak_mb": {
            "dense": round(d_peak / 2**20, 3),
            "sparse": round(s_peak / 2**20, 3)},
        "device_rows_per_version": {"dense": M_big, "sparse": k_max},
    }
    print(f"fleet-scale semi-async: M={M_big}, K={quorum} "
          f"(k_max={k_max}, ring={cap}), {versions} versions in "
          f"{wall:.1f}s wall, final loss {out['final_loss']:.4f}")
    print(f"host timeline peak: dense {d_peak / 2**20:.1f} MB vs sparse "
          f"{s_peak / 2**20:.2f} MB ({d_peak / max(s_peak, 1):.0f}x); "
          f"device client rows/version: dense {M_big} vs sparse {k_max} "
          f"({M_big // k_max}x)")
    return out


def smoke(rounds=8, seed=0):
    """The CI gate: at full quorum (K=0 ≡ wait-for-all) and discount 1.0
    the event-driven path must reproduce the synchronous scan — identical
    records in identical flatten order, so the trajectories agree to the
    1-ulp weight-normalization rounding (host f64 vs device f32 division;
    <=1e-5 is the acceptance bar) — and a K<M run must pace strictly
    faster than the barrier on a tiered fleet."""
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    kw = dict(tau=2, rounds=rounds, seed=seed)
    sync = _arm(cfg, params, ds, parts, key, aggregation="seed_replay", **kw)
    asy = _arm(cfg, params, ds, parts, key, mode="async",
               algorithm="async_mu_splitfed", aggregation="seed_replay",
               quorum=0, staleness_discount=1.0, **kw)
    diff = float(np.max(np.abs(np.array(sync["loss"]) - np.array(asy["loss"]))))
    assert diff <= 1e-5, f"async@K=M != scan trajectory (max diff {diff:.2e})"
    part = _arm(cfg, params, ds, parts, key, mode="async",
                algorithm="async_mu_splitfed", aggregation="seed_replay",
                quorum=QUORUM, staleness_discount=DISCOUNT, **kw)
    assert part["total_time"] < sync["total_time"], \
        "quorum commits must pace faster than the sync barrier"
    print(f"smoke: async@K=M == scan (max traj diff {diff:.1e} <= 1e-5); "
          f"K={QUORUM} sim time {part['total_time']:.1f}s vs sync "
          f"{sync['total_time']:.1f}s over {rounds} versions")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: the async==sync full-quorum gate "
                         "only, no json write")
    ap.add_argument("--clients", type=int, default=0,
                    help="fleet-scale arm instead of the tau grid: run the "
                         "semi-async engine at this fleet size with K=64 "
                         "(sparse timeline only)")
    ap.add_argument("--timeline", default="sparse",
                    choices=["sparse", "dense"],
                    help="timeline backend for the --clients arm (dense is "
                         "refused with the O(V*M) estimate)")
    ap.add_argument("--scale-versions", type=int, default=6,
                    help="versions for the --clients arm")
    ap.add_argument("--out", default="bench_fig6.json")
    ap.add_argument("--perf-out", default="perf_iterations.json")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke()
        return None
    if args.clients:
        return clients_arm(M_big=args.clients, quorum=64,
                           versions=args.scale_versions, seed=args.seed,
                           timeline=args.timeline)

    res = run(rounds=args.rounds, seed=args.seed)
    print(f"population: {res['population']}")
    print(f"target loss (best sync arm): {res['target_loss']:.4f}\n")
    print(f"{'arm':>16s} {'rounds':>6s} {'total_t':>8s} {'final':>7s} "
          f"{'wall_to_tgt':>11s}")
    for name, a in res["arms"].items():
        w = a["wall_to_target"]
        wtxt = f"{w:11.1f}" if np.isfinite(w) else f"{'never':>11s}"
        print(f"{name:>16s} {len(a['loss']):6d} {a['total_time']:8.1f} "
              f"{a['final_loss']:7.4f} {wtxt}")

    sync_w = {n: a["wall_to_target"] for n, a in res["arms"].items()
              if n.startswith("sync")}
    async_w = {n: a["wall_to_target"] for n, a in res["arms"].items()
               if n.startswith("async")}
    best_sync = min(sync_w, key=sync_w.get)
    best_async = min(async_w, key=async_w.get)
    speedup = sync_w[best_sync] / async_w[best_async]
    print(f"\nbest sync {best_sync} {sync_w[best_sync]:.1f}s vs semi-async "
          f"{best_async} {async_w[best_async]:.1f}s -> {speedup:.2f}x "
          f"less simulated wall-clock to the same loss")
    json.dump(res, open(args.out, "w"))

    row = {
        "variant": "v6", "bench": "fig6_async",
        "arch": "tiny(3L,d32,seq32)", "clients": M, "quorum": QUORUM,
        "staleness_discount": DISCOUNT, "t_server": T_SERVER,
        "rounds_sync": args.rounds, "rounds_async": 3 * args.rounds,
        "population": res["population"], "target_loss": res["target_loss"],
        "wall_to_target": {n: (a["wall_to_target"]
                               if np.isfinite(a["wall_to_target"]) else None)
                           for n, a in res["arms"].items()},
        "best_sync": best_sync, "best_async": best_async,
        "speedup": round(float(speedup), 3),
    }
    rows = (json.load(open(args.perf_out))
            if os.path.exists(args.perf_out) else [])
    rows.append(row)
    json.dump(rows, open(args.perf_out, "w"), indent=1)
    print(f"\nappended v6 row to {args.perf_out}")
    return res


if __name__ == "__main__":
    main()
