"""Fig. 5 (beyond-paper): adaptive τ on a heterogeneous tiered population.

The paper's §5 claim is that MU-SplitFed "effectively mitigates [straggler]
impact through adaptive tuning of τ". This benchmark makes that claim a
measurement: a tiered ClientPopulation — a fast tier plus a much slower
tier whose availability follows a bursty Markov chain — trained with every
static τ ∈ {1, 2, 4, 8} and with engine.AdaptiveTau re-planning τ at chunk
boundaries from the observed straggler gap (Eq. 12's τ* = t_straggler /
t_server via straggler.plan_tau).

Reported per arm: the loss curve, simulated wall-clock to the target loss,
and (for the adaptive arm) the τ trajectory. Statics lose on one side or
the other: small τ wastes the straggler wait (few server steps per slow
round), large τ pads fast rounds to τ·t_server when the slow tier is in a
dropout burst. The adaptive arm tracks the gap and takes the Eq.-12
round-time everywhere, so it reaches the target in less simulated time
than every static arm.

    PYTHONPATH=src python -m benchmarks.fig5_adaptive_tau [--rounds 60]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import (make_setup, run_mu_splitfed_result,
                               wall_to_target)
from repro.core import engine
from repro.core.population import ClientPopulation, Cohort, DelayModel

T_SERVER = 0.25
LR_SERVER = 5e-3           # shared flat η_s: every arm takes the same-size
LR_CLIENT = 1e-3           # server steps; arms differ only in how many
TAU_MAX = 16               # steps fit into each round's straggler wait

# the fleet: 4 fast clients always on, 2 clients ~13× slower whose
# availability is a bursty Markov chain (mean dwell ~5-7 rounds per phase)
# — the regime where no single static τ is right: during slow-up phases
# τ* ≈ 16, during dropout bursts τ* collapses with the straggler gap
POPULATION = ClientPopulation(cohorts=(
    Cohort(name="fast", n=4, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=2, delay=DelayModel(base=4.0, scale=0.5),
           availability="markov", p_dropout=0.15, p_recover=0.20),
))

STATIC_TAUS = (1, 2, 4, 8)


def _arm(cfg, params, ds, parts, key, *, tau, rounds, seed, controller=None):
    res = run_mu_splitfed_result(
        cfg, params, ds, parts, key, M=POPULATION.n_clients, tau=tau, cut=1,
        rounds=rounds, lr_server=LR_SERVER, lr_client=LR_CLIENT,
        lr_global=1.0, population=POPULATION, controller=controller,
        t_server=T_SERVER, seed=seed, chunk_size=4)
    taus = (res.tau_per_round if res.tau_per_round is not None
            else np.full(rounds, tau, np.int64))
    return {
        "loss": [float(x) for x in res.round_loss],
        "wall": [float(x) for x in np.cumsum(res.round_times)],
        "tau_per_round": [int(t) for t in taus],
        "server_steps": int(taus.sum()),
        "total_time": float(res.sim_time),
    }


def run(rounds=60, seed=0):
    cfg, params, ds, parts, key = make_setup(M=POPULATION.n_clients,
                                             seed=seed)
    arms = {}
    for tau in STATIC_TAUS:
        arms[f"static_tau{tau}"] = _arm(cfg, params, ds, parts, key,
                                        tau=tau, rounds=rounds, seed=seed)
    ctl = engine.AdaptiveTau(tau_max=TAU_MAX, couple_lr=False, quantize=True)
    arms["adaptive"] = _arm(cfg, params, ds, parts, key, tau=1,
                            rounds=rounds, seed=seed, controller=ctl)

    # target: the best STATIC arm's achieved (smoothed) final loss — by
    # construction at least one static arm reaches it, and the question
    # becomes "how much sooner does adaptive τ get there?" (every arm sees
    # the same schedule; only the τ policy differs)
    target = float(min(np.mean(arms[f"static_tau{t}"]["loss"][-3:])
                       for t in STATIC_TAUS))
    for a in arms.values():
        a["wall_to_target"] = wall_to_target(
            a["loss"], np.diff([0.0] + a["wall"]), target)

    return {"target_loss": target, "t_server": T_SERVER,
            "population": POPULATION.describe(), "arms": arms}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="bench_fig5.json")
    args = ap.parse_args(argv)
    res = run(rounds=args.rounds, seed=args.seed)

    print(f"population: {res['population']}")
    print(f"target loss: {res['target_loss']:.4f}\n")
    print(f"{'arm':>14s} {'steps':>6s} {'total_t':>8s} {'final':>7s} "
          f"{'wall_to_tgt':>11s}")
    for name, a in res["arms"].items():
        w = a["wall_to_target"]
        print(f"{name:>14s} {a['server_steps']:6d} {a['total_time']:8.1f} "
              f"{np.mean(a['loss'][-3:]):7.4f} "
              f"{w:11.1f}" if np.isfinite(w) else
              f"{name:>14s} {a['server_steps']:6d} {a['total_time']:8.1f} "
              f"{np.mean(a['loss'][-3:]):7.4f} {'never':>11s}")
    taus = res["arms"]["adaptive"]["tau_per_round"]
    print(f"\nadaptive tau trajectory: {taus}")
    best_static = min(res["arms"][f"static_tau{t}"]["wall_to_target"]
                      for t in STATIC_TAUS)
    adap = res["arms"]["adaptive"]["wall_to_target"]
    print(f"\nbest static wall-to-target {best_static:.1f}s vs adaptive "
          f"{adap:.1f}s -> speedup {best_static / adap:.2f}x")
    json.dump(res, open(args.out, "w"))
    return res


if __name__ == "__main__":
    main()
