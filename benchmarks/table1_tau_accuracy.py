"""Paper Table 1: effect of τ at a FIXED number of communication rounds.

Paper finding: at the paper's cut (small client prefix), τ=2 is best and
larger τ degrades — the τ × cut-layer coupling of Cor. 4.2. Here the metric
is final LM loss after R rounds (lower = better) on the synthetic task.

    PYTHONPATH=src python -m benchmarks.table1_tau_accuracy [--rounds 30]
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import make_setup, run_mu_splitfed


def run(rounds=30, taus=(1, 2, 3, 4), M=4, seed=0):
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    out = {}
    for tau in taus:
        losses = run_mu_splitfed(cfg, params, ds, parts, key, M=M, tau=tau,
                                 cut=1, rounds=rounds, seed=seed)
        out[tau] = {"final_loss": sum(losses[-3:]) / 3,
                    "curve": losses}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--out", default="bench_table1.json")
    args = ap.parse_args(argv)
    res = run(rounds=args.rounds)
    print(f"{'tau':>4s} {'final_loss':>11s}   (vanilla SplitFed = tau 1)")
    for tau, r in res.items():
        print(f"{tau:4d} {r['final_loss']:11.4f}")
    json.dump({str(k): v for k, v in res.items()}, open(args.out, "w"))
    return res


if __name__ == "__main__":
    main()
