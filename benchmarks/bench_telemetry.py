"""Telemetry overhead gate: engine run with telemetry ON vs OFF on the
sparse-timeline async path — the hot path the producers instrument.

The ON arm is the full observability stack: a TelemetrySink attached to
run_rounds (sim + measured producers, block_until_ready-bracketed
dispatch) AND an enabled SpanTracer installed over the engine/DES spans.
The OFF arm is the default: no sink, no tracer — zero clock reads on the
chunk loop. Both arms share one algorithm instance, so the jitted chunk
executables compile once and every timed rep measures steady-state
dispatch only; arms alternate rep-by-rep and the gate compares
best-of-``reps`` (the usual guards against shared-machine noise).

The CI job fails the build when overhead exceeds the budget:

    PYTHONPATH=src python -m benchmarks.bench_telemetry --gate \
        --trace-out telemetry-trace.json

``--trace-out`` writes the last ON rep's Chrome trace (chrome://tracing /
perfetto) as the job artifact.
"""
from __future__ import annotations

import argparse
import json

import jax

import repro.obs as obs
from benchmarks.common import batch_fn_for, make_setup
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel

BUDGET = 0.02          # telemetry-on may cost at most 2% wall time
M, QUORUM, ROUNDS, CHUNK = 32, 8, 64, 8

POP = ClientPopulation(cohorts=(
    Cohort(name="fast", n=M - M // 4, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=M // 4, delay=DelayModel(base=4.0, scale=0.5)),
))


def setup(seed=0):
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed, seq=16,
                                             layers=2)
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=POP,
                    quorum=QUORUM, staleness_discount=0.5,
                    timeline="sparse")
    sched = strag.make_schedule(seed, ROUNDS,
                                population=strag.ClientPopulation.resolve(sfl),
                                t_server=0.25, t_comm=0.05)
    batch_fn = batch_fn_for(ds, parts, 1, seed)
    # ONE shared instance: both arms reuse the same compiled chunk
    # executables, so the comparison is pure host-side overhead
    algo = engine.get_algorithm("async_mu_splitfed",
                                aggregation="seed_replay")
    return algo, cfg, sfl, params, batch_fn, sched, key


def run(algo, cfg, sfl, params, batch_fn, sched, key, *, telemetry=None):
    res = engine.run_rounds(algo, cfg, sfl, params, batch_fn, sched, key,
                            rounds=ROUNDS, chunk_size=CHUNK, mode="async",
                            telemetry=telemetry)
    jax.block_until_ready(res.params)
    return res


def bench(reps=7, seed=0, trace_out=""):
    args = setup(seed)
    tracer = obs.SpanTracer()

    def arm_off():
        prev = obs.install(None)
        try:
            m = obs.measure(run, *args)
        finally:
            obs.install(prev)
        return m.seconds

    def arm_on():
        sink = obs.TelemetrySink()
        tracer.clear()
        prev = obs.install(tracer)
        try:
            m = obs.measure(run, *args, telemetry=sink)
        finally:
            obs.install(prev)
        assert sink.records("sim") and sink.records("measured"), \
            "telemetry arm produced no records"
        return m.seconds

    # warm both arms: compiles the chunk executables and touches every
    # code path once before anything is timed
    arm_off()
    arm_on()
    off, on = [], []
    for _ in range(reps):                       # alternate: drift hits both
        off.append(arm_off())
        on.append(arm_on())
    if trace_out:
        n = tracer.export_chrome(trace_out)
        print(f"trace artifact: {n} spans -> {trace_out}")
    best_off, best_on = min(off), min(on)
    return {
        "bench": "bench_telemetry", "mode": "async/sparse",
        "clients": M, "quorum": QUORUM, "rounds": ROUNDS, "chunk": CHUNK,
        "reps": reps,
        "off_s": round(best_off, 4), "on_s": round(best_on, 4),
        "overhead": round((best_on - best_off) / best_off, 4),
        "budget": BUDGET,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when overhead exceeds the 2%% budget")
    ap.add_argument("--trace-out", default="",
                    help="write the ON arm's Chrome trace here (CI artifact)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    row = bench(reps=args.reps, seed=args.seed, trace_out=args.trace_out)
    print(json.dumps(row, indent=1))
    if args.out:
        json.dump(row, open(args.out, "w"), indent=1)
    if args.gate and row["overhead"] > BUDGET:
        raise SystemExit(
            f"telemetry overhead {row['overhead']:.2%} exceeds the "
            f"{BUDGET:.0%} budget (off {row['off_s']}s -> on "
            f"{row['on_s']}s)")
    return row


if __name__ == "__main__":
    main()
