"""Fig. 7 (beyond-paper): graceful degradation under injected faults —
crash rate × quorum-timeout sweep over the semi-async engine.

The paper's premise is that the Split Server must not block on its
slowest clients; at fleet scale the dominant failure mode is harsher
(PAPERS.md, "Optimizing SFL with Unstable Client Participation"):
clients that *never* deliver. This benchmark injects crash-after-fetch
faults (core/faults.py) into the event timeline and measures what the
two degradation knobs buy:

  quorum_timeout  a commit whose quorum hasn't filled by t + timeout
                  proceeds with whatever arrived (weights renormalized)
                  — liveness at the cost of thinner aggregation.
  AdaptiveQuorum  shrinks the commit quorum K toward the observed
                  delivery rate, so commits stay quorum-paced instead of
                  riding the timeout deadline every version.

Reported per arm: loss curve, simulated wall-clock, delivered/started
ratio and the full fault counter set from the telemetry sink — the
loss-vs-wall-clock degradation curves land in bench_fig7.json.

    PYTHONPATH=src python -m benchmarks.fig7_faults [--rounds 60]
    PYTHONPATH=src python -m benchmarks.fig7_faults --smoke   # CI gate:
        FaultPlan.none() bit-exact with faults=None on sync scan,
        async-dense, and async-sparse; liveness (all rounds complete,
        monotone commits) under crash=0.2 with a quorum timeout
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import make_setup, run_mu_splitfed_result
from repro.core.engine import AdaptiveQuorum
from repro.core.faults import FaultPlan
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.obs.telemetry import TelemetrySink

T_SERVER = 0.25
LR_SERVER = 5e-3
LR_CLIENT = 1e-3
CUT = 1
QUORUM = 6                  # K of M=8
DISCOUNT = 0.5
TAU = 2

CRASH_RATES = (0.0, 0.1, 0.2, 0.4)
TIMEOUTS = (0.5, 2.0)

POPULATION = ClientPopulation(cohorts=(
    Cohort(name="fast", n=6, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=2, delay=DelayModel(base=4.0, scale=0.5)),
))
M = POPULATION.n_clients

FAULT_FIELDS = ("started", "evicted", "crashed", "lost", "corrupt",
                "dups", "retries", "timeouts")


def _counters(sink: TelemetrySink) -> dict:
    recs = sink.records("sim")
    return {f: int(sum(getattr(r, f) for r in recs)) for f in FAULT_FIELDS}


def _arm(cfg, params, ds, parts, key, *, rounds, seed, mode="async",
         **kw):
    sink = TelemetrySink(capacity=4096)
    res = run_mu_splitfed_result(
        cfg, params, ds, parts, key, M=M, tau=TAU, cut=CUT, rounds=rounds,
        lr_server=LR_SERVER, lr_client=LR_CLIENT, lr_global=1.0,
        population=POPULATION, t_server=T_SERVER, seed=seed, chunk_size=4,
        mode=mode, telemetry=sink, **kw)
    c = _counters(sink)
    dropped = c["crashed"] + c["lost"] + c["corrupt"] + c["evicted"]
    return {
        "loss": [float(x) for x in res.round_loss],
        "round_times": [float(x) for x in res.round_times],
        "total_time": float(res.sim_time),
        "final_loss": float(np.mean(res.round_loss[-3:])),
        "counters": c,
        "delivery_rate": (round(1.0 - dropped / c["started"], 4)
                          if c["started"] else 1.0),
    }


def run(rounds=60, seed=0):
    """The degradation sweep: crash rate × quorum-timeout, plus an
    AdaptiveQuorum arm per crash rate at the tight timeout."""
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    base = dict(rounds=rounds, seed=seed, algorithm="async_mu_splitfed",
                aggregation="seed_replay", quorum=QUORUM,
                staleness_discount=DISCOUNT)
    arms = {}
    for crash in CRASH_RATES:
        plan = FaultPlan(crash=crash) if crash else None
        for to in TIMEOUTS:
            arms[f"crash{crash:g}_to{to:g}"] = _arm(
                cfg, params, ds, parts, key, faults=plan,
                quorum_timeout=to, **base)
        if crash:
            arms[f"crash{crash:g}_to{TIMEOUTS[0]:g}_adaptiveK"] = _arm(
                cfg, params, ds, parts, key, faults=plan,
                quorum_timeout=TIMEOUTS[0],
                controller=AdaptiveQuorum(), **base)
    return {"t_server": T_SERVER, "quorum": QUORUM,
            "staleness_discount": DISCOUNT, "tau": TAU,
            "crash_rates": list(CRASH_RATES), "timeouts": list(TIMEOUTS),
            "population": POPULATION.describe(), "arms": arms}


def smoke(rounds=12, seed=0):
    """The chaos-smoke CI gate.

    1. Zero-fault equivalence: FaultPlan.none() must be BIT-EXACT with
       faults=None on every execution path — sync scan, async dense,
       async sparse. The fault layer may not perturb a clean run by so
       much as one extra RNG draw.
    2. Liveness under faults: crash=0.2 with a quorum timeout completes
       all rounds, commit times strictly increase, and every version's
       duration is finite — no stall, no deadlock.

    Returns the degradation record the CI job uploads as its artifact.
    """
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    kw = dict(rounds=rounds, seed=seed)
    paths = {
        "sync_scan": dict(mode="scan", aggregation="seed_replay"),
        "async_dense": dict(mode="async", algorithm="async_mu_splitfed",
                            aggregation="seed_replay", quorum=QUORUM,
                            staleness_discount=DISCOUNT),
        "async_sparse": dict(mode="async", algorithm="async_mu_splitfed",
                             aggregation="seed_replay", quorum=QUORUM,
                             staleness_discount=DISCOUNT,
                             timeline="sparse"),
    }
    for name, pkw in paths.items():
        clean = _arm(cfg, params, ds, parts, key, faults=None, **pkw, **kw)
        inert = _arm(cfg, params, ds, parts, key, faults=FaultPlan.none(),
                     **pkw, **kw)
        assert clean["loss"] == inert["loss"] and \
            clean["round_times"] == inert["round_times"], \
            f"{name}: FaultPlan.none() != faults=None (not bit-exact)"
        assert all(v == 0 for f, v in inert["counters"].items()
                   if f != "started"), \
            f"{name}: zero-fault run reported fault counters"
    print(f"smoke: FaultPlan.none() bit-exact with faults=None on "
          f"{', '.join(paths)}")

    live = {}
    for name in ("async_dense", "async_sparse"):
        a = _arm(cfg, params, ds, parts, key,
                 faults=FaultPlan(crash=0.2), quorum_timeout=1.0,
                 **paths[name], **kw)
        assert len(a["loss"]) == rounds, \
            f"{name}: {len(a['loss'])}/{rounds} rounds under crash=0.2"
        ct = np.cumsum(a["round_times"])
        assert np.all(np.isfinite(ct)) and np.all(np.diff(ct) > 0), \
            f"{name}: commit times not finite/monotone under faults"
        assert a["counters"]["crashed"] > 0, \
            f"{name}: crash=0.2 injected no crashes over {rounds} rounds"
        live[name] = a
        print(f"smoke: {name} liveness OK under crash=0.2 — "
              f"{rounds}/{rounds} rounds, delivery {a['delivery_rate']}, "
              f"counters {a['counters']}")
    assert live["async_dense"]["counters"] == \
        live["async_sparse"]["counters"], \
        "dense and sparse disagree on fault accounting"
    return {"gate": "zero-fault-bitexact+liveness", "rounds": rounds,
            "crash": 0.2, "quorum_timeout": 1.0, "quorum": QUORUM,
            "arms": live}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: zero-fault bit-exactness + "
                         "liveness gates; writes the degradation record "
                         "to --out")
    ap.add_argument("--out", default="bench_fig7.json")
    args = ap.parse_args(argv)
    if args.smoke:
        res = smoke(seed=args.seed)
        json.dump(res, open(args.out, "w"), indent=1)
        print(f"smoke degradation record -> {args.out}")
        return res

    res = run(rounds=args.rounds, seed=args.seed)
    print(f"population: {res['population']}\n")
    print(f"{'arm':>24s} {'total_t':>8s} {'final':>7s} {'deliv':>6s} "
          f"{'timeouts':>8s} {'crashed':>7s}")
    for name, a in res["arms"].items():
        print(f"{name:>24s} {a['total_time']:8.1f} {a['final_loss']:7.4f} "
              f"{a['delivery_rate']:6.3f} {a['counters']['timeouts']:8d} "
              f"{a['counters']['crashed']:7d}")
    json.dump(res, open(args.out, "w"), indent=1)
    print(f"\ndegradation curves -> {args.out}")
    return res


if __name__ == "__main__":
    main()
