"""Paper Fig. 3 / Table 4: interaction between cut layer L_c and server
iterations τ — communication rounds to reach a target loss.

Paper findings to reproduce: (i) for fixed cut, increasing τ first helps
then hurts; (ii) earlier cuts (deeper server) help; (iii) the optimal τ
grows as the cut moves earlier (Cor. 4.2's d_c = √(d/τ) coupling).

    PYTHONPATH=src python -m benchmarks.fig3_cutlayer_tau [--rounds 40]
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import make_setup, rounds_to_target, run_mu_splitfed
from repro.core import theory


def run(rounds=40, cuts=(1, 2, 3), taus=(1, 2, 4), target=None, M=4, seed=0):
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed, layers=3)
    # target: 80% of the progress the τ=1, cut=2 baseline makes in `rounds`
    # (a bar the baseline only clears near its end, so the grid spreads)
    base = run_mu_splitfed(cfg, params, ds, parts, key, M=M, tau=1, cut=2,
                           rounds=rounds, seed=seed)
    final = sum(base[-3:]) / 3
    tgt = target or (base[0] - 0.8 * (base[0] - final))
    grid = {}
    for cut in cuts:
        for tau in taus:
            losses = run_mu_splitfed(cfg, params, ds, parts, key, M=M,
                                     tau=tau, cut=cut, rounds=rounds,
                                     seed=seed)
            grid[f"cut{cut}_tau{tau}"] = {
                "rounds_to_target": rounds_to_target(losses, tgt),
                "final_loss": sum(losses[-3:]) / 3}
    return {"target_loss": tgt, "grid": grid,
            "theory_tau_star": {c: theory.optimal_tau_for_cut(
                *_dims(cfg, c)) for c in cuts}}


def _dims(cfg, cut):
    from repro.models import split_dims
    d_c, d_s = split_dims(cfg, cut)
    return d_c + d_s, d_c


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--out", default="bench_fig3.json")
    args = ap.parse_args(argv)
    res = run(rounds=args.rounds)
    print(f"target loss: {res['target_loss']:.4f}")
    print(f"{'cell':>14s} {'rounds_to_tgt':>13s} {'final_loss':>11s}")
    for k, v in res["grid"].items():
        print(f"{k:>14s} {v['rounds_to_target']:13d} {v['final_loss']:11.4f}")
    print("theory tau* per cut:", res["theory_tau_star"])
    json.dump(res, open(args.out, "w"))
    return res


if __name__ == "__main__":
    main()
