"""Paper Table 2 (Appendix A): Split-Server communication complexity
comparison, plus this system's measured per-round wire bytes.

Theory columns evaluate the Table-2 formulas; the measured column counts
the actual MU-SplitFed protocol bytes per round:
  up   : 3 embeddings (h, h+, h-) of (b, S, D) bf16 per client
  down : 1 scalar δ_c per client (+ the aggregated client model broadcast
         — or its seed-replay compression, which is O(Mτ) scalars).
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.core import theory
from repro.models import split_dims


def theory_table(d=10**6, tau=4, M=10, K=5, eps=0.1) -> dict:
    methods = ["sfl_v1", "sfl_v2", "mu_splitfed_tau1", "mu_splitfed",
               "mu_splitfed_tau_to_d"]
    return {m: theory.comm_complexity(m, d, tau, M, K, eps) for m in methods}


def measured_protocol(arch="paper-opt-1.3b", cut=2, b=8, S=128, M=10,
                      tau=4) -> dict:
    cfg = get_config(arch)
    d_c, d_s = split_dims(cfg, cut)
    embed_bytes = b * S * cfg.d_model * 2
    up = 3 * embed_bytes * M
    down_scalar = 4 * M
    dense_broadcast = d_c * 2           # aggregated client model (Eq. 7)
    replay_broadcast = M * 8            # (key, coeff) per client
    return {
        "per_round_up_bytes": up,
        "per_round_down_scalars_bytes": down_scalar,
        "client_agg_dense_bytes": dense_broadcast,
        "client_agg_seed_replay_bytes": replay_broadcast,
        "compression_ratio": dense_broadcast / replay_broadcast,
        "note": ("server-side aggregation stays inside the Split Server "
                 "(pod-local); seed-replay reduces the cross-pod reduce to "
                 "O(Mτ) scalars — Appendix A realized"),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_table2.json")
    args = ap.parse_args(argv)
    th = theory_table()
    meas = measured_protocol()
    print(f"{'method':>22s} {'comm cost (rel)':>16s}")
    base = th["mu_splitfed_tau1"]
    for k, v in th.items():
        print(f"{k:>22s} {v / base:16.4f}")
    print(f"\nmeasured protocol (paper-opt-1.3b, M=10, tau=4):")
    for k, v in meas.items():
        if isinstance(v, (int, float)):
            print(f"  {k:32s} {v:,.0f}")
    json.dump({"theory": th, "measured": meas}, open(args.out, "w"))
    return th, meas


if __name__ == "__main__":
    main()
