"""Shared harness for the paper-reproduction benchmarks.

The paper's vision tasks (CIFAR/F-MNIST on AlexNet) are replaced by the
offline-container equivalents: a synthetic Markov LM (loss-based targets)
and a synthetic sentiment task (the SST-2 stand-in for the OPT-1.3B
experiments). The *system* quantities the paper measures — communication
rounds, wall-clock under stragglers, client memory — are model-agnostic and
reproduced faithfully; accuracy columns become loss columns. Documented in
EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import engine
from repro.core import straggler as strag
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params


def tiny_cfg(vocab=64, layers=3):
    return get_config("olmo-1b", smoke=True).replace(
        n_layers=layers, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=vocab, dtype="float32")


def make_setup(M=4, batch=2, seq=32, seed=0, vocab=64, layers=3):
    cfg = tiny_cfg(vocab, layers)
    key = jax.random.PRNGKey(seed)
    params = untie_params(cfg, init_params(cfg, key))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, seed=seed)
    parts = dirichlet_partition(np.arange(512) % 8, M, alpha=0.5, seed=seed)
    return cfg, params, ds, parts, key


def batch_fn_for(ds, parts, batch, seed):
    """Stateless round->host-batch closure for the engine."""
    return lambda r: make_client_batches(ds, parts, r, batch, seed)


def run_mu_splitfed_result(cfg, params, ds, parts, key, *, M, tau, cut,
                           rounds, batch=2, lr_server=5e-3, lr_client=1e-3,
                           lr_global=1.0, participation=1.0, population=None,
                           controller=None, straggler_scale=0.0,
                           t_server=0.1, t_comm=0.0, seed=0,
                           chunk_size=8, algorithm="mu_splitfed",
                           mode="scan", aggregation=None, quorum=0,
                           staleness_discount=1.0, timeline="dense",
                           k_max=0, ring_capacity=0, faults=None,
                           quorum_timeout=0.0, max_retries=3,
                           telemetry=None) -> engine.EngineResult:
    """Full EngineResult for one MU-SplitFed-family run through the engine.

    The fleet resolves through the one ClientPopulation.resolve path: an
    explicit ``population`` (heterogeneous cohorts / Markov availability)
    or the deprecated scalar shorthand. ``controller`` (e.g.
    engine.AdaptiveTau) re-plans τ at chunk boundaries. For the
    event-driven semi-async substrate pass algorithm='async_mu_splitfed',
    mode='async' and the quorum / staleness_discount policy knobs
    (core/events.py); every arm of a sync-vs-async comparison then shares
    the same schedule draw.
    """
    if aggregation is None:         # async's record store IS seed replay
        aggregation = ("seed_replay" if algorithm == "async_mu_splitfed"
                       else "dense")
    sfl = SFLConfig(n_clients=M, tau=tau, cut_units=cut,
                    lr_server=lr_server, lr_client=lr_client,
                    lr_global=lr_global, participation=participation,
                    straggler_rate=straggler_scale, population=population,
                    quorum=quorum, staleness_discount=staleness_discount,
                    timeline=timeline, k_max=k_max,
                    ring_capacity=ring_capacity, faults=faults,
                    quorum_timeout=quorum_timeout, max_retries=max_retries)
    sched = strag.make_schedule(seed, rounds,
                                population=strag.ClientPopulation.resolve(sfl),
                                t_server=t_server, t_comm=t_comm)
    return engine.run_rounds(algorithm, cfg, sfl, params,
                             batch_fn_for(ds, parts, batch, seed), sched, key,
                             rounds=rounds, chunk_size=chunk_size,
                             mode=mode, controller=controller,
                             aggregation=aggregation, telemetry=telemetry)


def run_mu_splitfed(cfg, params, ds, parts, key, *, M, tau, cut, rounds,
                    batch=2, lr_server=5e-3, lr_client=1e-3, lr_global=1.0,
                    participation=1.0, seed=0, chunk_size=8) -> List[float]:
    """Returns the per-round mean client loss curve (engine, fused scan)."""
    res = run_mu_splitfed_result(
        cfg, params, ds, parts, key, M=M, tau=tau, cut=cut, rounds=rounds,
        batch=batch, lr_server=lr_server, lr_client=lr_client,
        lr_global=lr_global, participation=participation, seed=seed,
        chunk_size=chunk_size)
    return [float(x) for x in res.round_loss]


def rounds_to_target(losses: List[float], target: float) -> int:
    """First round whose smoothed loss reaches the target (or len+1)."""
    smooth = np.convolve(losses, np.ones(3) / 3, mode="valid")
    hits = np.where(smooth <= target)[0]
    return int(hits[0]) + 1 if len(hits) else len(losses) + 1


def wall_to_target(losses, round_times, target: float) -> float:
    """Simulated wall-clock at which the smoothed loss first reaches the
    target (inf if it never does) — the paper's straggler-resilience
    metric: progress per unit *time*, not per round."""
    smooth = np.convolve(losses, np.ones(3) / 3, mode="valid")
    hits = np.where(smooth <= target)[0]
    if not len(hits):
        return float("inf")
    return float(np.cumsum(round_times)[hits[0] + 2])


def timed(fn, *args, reps=3):
    fn(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us
