"""Per-round host overhead: legacy python loop vs the engine's fused
multi-round scan (perf ladder v5).

Both paths run the SAME algorithm round body (engine adapters) over the
SAME precomputed schedule and keys; the only difference is orchestration —
one jit dispatch + host sync per round (python) vs one per chunk of C
rounds (scan, donated params). The equivalence gate asserts the two loss
trajectories agree to <=1e-5 before any number is reported; rows land in
perf_iterations.json as rung v5.

    PYTHONPATH=src python -m benchmarks.bench_rounds \
        [--rounds 32] [--chunk 8] [--algorithm mu_splitfed]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from benchmarks.common import batch_fn_for, make_setup
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag
from repro.obs import measure


def run_once(algo, cfg, sfl, params, batch_fn, sched, key, *, rounds, mode,
             chunk):
    """(result, seconds, host_peak_bytes) — the shared obs.measure pair."""
    def body():
        res = engine.run_rounds(algo, cfg, sfl, params, batch_fn, sched, key,
                                rounds=rounds, mode=mode, chunk_size=chunk)
        jax.block_until_ready(res.params)
        return res
    m = measure(body)
    return m.result, m.seconds, m.peak_bytes


def run(rounds=32, chunk=8, M=4, tau=2, algorithm="mu_splitfed", seed=0,
        reps=3, layers=2, seq=16, batch=1):
    # deliberately small round body: this bench isolates the HOST overhead
    # (dispatch + sync + un-donated copies) that the fused scan removes —
    # at production model sizes that overhead is the same absolute ms but
    # hidden under compute
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed, seq=seq,
                                             layers=layers)
    sfl = SFLConfig(n_clients=M, tau=tau, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0)
    sched = strag.make_schedule(seed, rounds, M, straggler_scale=2.0,
                                participation=0.5)
    batch_fn = batch_fn_for(ds, parts, batch, seed)
    # one shared adapter instance: the engine caches its jitted round/chunk
    # executables on it, so the timed second run pays zero compilation
    algo = engine.get_algorithm(algorithm)

    out = {}
    for mode in ("python", "scan"):
        # warmup run compiles every chunk shape; the timed runs measure
        # steady-state dispatch + host-sync overhead only (best of `reps`,
        # the usual guard against shared-machine noise)
        run_once(algo, cfg, sfl, params, batch_fn, sched, key,
                 rounds=rounds, mode=mode, chunk=chunk)
        best, best_peak = None, 0
        for _ in range(reps):
            res, dt, peak = run_once(algo, cfg, sfl, params, batch_fn,
                                     sched, key, rounds=rounds, mode=mode,
                                     chunk=chunk)
            if best is None or dt < best:
                best, best_peak = dt, peak
        out[mode] = {"res": res, "total_s": best, "peak_bytes": best_peak,
                     "per_round_ms": best / rounds * 1e3}

    # equivalence gate: the fused scan must reproduce the python loop's
    # loss trajectory before its speed means anything
    diff = float(np.max(np.abs(out["python"]["res"].round_loss
                               - out["scan"]["res"].round_loss)))
    assert diff <= 1e-5, f"scan != python trajectory (max diff {diff:.2e})"

    return {
        "variant": "v5", "bench": "bench_rounds", "algorithm": algorithm,
        "arch": f"tiny({layers}L,d32,seq{seq})", "rounds": rounds,
        "chunk": chunk, "tau": tau, "clients": M,
        "per_round_ms_python": round(out["python"]["per_round_ms"], 3),
        "per_round_ms_scan": round(out["scan"]["per_round_ms"], 3),
        "host_peak_mb_python": round(out["python"]["peak_bytes"] / 2**20, 3),
        "host_peak_mb_scan": round(out["scan"]["peak_bytes"] / 2**20, 3),
        "speedup": round(out["python"]["per_round_ms"]
                         / out["scan"]["per_round_ms"], 3),
        "max_loss_traj_diff": diff,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--algorithm", default="mu_splitfed",
                    choices=sorted(engine.ALGORITHMS))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: few rounds, one rep, no json write "
                         "— runs only the scan==python equivalence gate")
    ap.add_argument("--out", default="perf_iterations.json")
    args = ap.parse_args(argv)
    if args.smoke:
        row = run(rounds=8, chunk=4, algorithm=args.algorithm, reps=1)
        print(json.dumps(row, indent=1))
        print("smoke: scan == python equivalence gate passed")
        return row
    row = run(rounds=args.rounds, chunk=args.chunk, algorithm=args.algorithm,
              reps=args.reps)
    print(json.dumps(row, indent=1))
    rows = json.load(open(args.out)) if os.path.exists(args.out) else []
    rows.append(row)
    json.dump(rows, open(args.out, "w"), indent=1)
    return row


if __name__ == "__main__":
    main()
