"""Host timeline-compiler benchmark: dense (V, M) rows vs the sparse
streaming DES (core/events.py), at fleet sizes M ∈ {1e3, 1e4, 1e5} plus a
compiler-only FLEET arm at M=1e6 (DES + lazy schedule stream, no device
scan).

Measures, per backend and fleet size:
  * compile throughput (versions/s) — the dense compiler pays an O(M)
    Python start loop plus a full re-sort of the pending set per version;
    the sparse DES pays O(K log M + E_v) per version: cohort-indexed idle
    sets for admission and one lexsort over the <= capacity pending slots
    for the quorum.
  * peak host memory (tracemalloc, which tracks numpy data since 1.22) —
    dense materializes (V, M) start/apply/staleness rows plus the O(E)
    event list; sparse streams (chunk, k_max) rows and keeps O(M) scan
    state, so the trace never materializes.

The dense compiler is REFUSED at M >= 1e5 with an O(V·M) size estimate
(SystemExit) — perf rung v7 measured it once at 152 s / 824 MB for V=48,
M=1e5 and that is the last time anyone should pay it. The perf rung v8
acceptance gate is the FLEET arm: >= 10x versions/s over the v7 sparse
DES extrapolated to M=1e6, with bounded memory (no (R, M) or (V, M)
materialization anywhere on the path — the lazy schedule protocol never
densifies a mask row).

    PYTHONPATH=src python -m benchmarks.bench_timeline            # full
    PYTHONPATH=src python -m benchmarks.bench_timeline --smoke    # CI gate

--smoke is the equivalence gate: timeline fields exactly equal after
densifying (grid over quorum x discount x fleet — this pits the
cohort-indexed idle sets against the dense compiler's per-client
reference scan, including a fast M=1e4 Markov-fleet pass), the engine's
sparse loss trajectory within 1e-5 of the dense async path on a tiered
fleet (they are bit-equal here: same records in the same flatten order,
and dyadic discount weights normalize exactly), and the loader's O(K)
subset staging bit-equal to indexing the fleet-width gather.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs import SFLConfig
from repro.core import events
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.obs import measure

T_SERVER = 0.25
QUORUM = 64
DISCOUNT = 0.5
VERSIONS = 48
CHUNK = 8
SIZES = (1_000, 10_000, 100_000)
FLEET_M = 1_000_000
DENSE_REFUSE_M = 100_000
# perf rung v7's recorded sparse-DES wall times (perf_iterations.json:
# variant v7, same constants as above) — the v8 fleet-arm gate
# extrapolates these linearly in M to the fleet size
V7_SPARSE_SEC = {10_000: 0.2521, 100_000: 0.3014}


def v7_extrapolated_sec(M: int) -> float:
    """v7 sparse-DES seconds for VERSIONS versions, linear in M."""
    (m0, s0), (m1, s1) = sorted(V7_SPARSE_SEC.items())
    return s0 + (s1 - s0) / (m1 - m0) * (M - m0)


def refuse_dense(M: int, versions: int) -> None:
    """The dense compiler materializes (V, M) start/apply/staleness rows
    plus an (R, M) f64 schedule; past DENSE_REFUSE_M that is a host-memory
    incident, not a benchmark arm."""
    if M >= DENSE_REFUSE_M:
        est = versions * M * (4 + 4 + 8) + 8 * 8 * M
        raise SystemExit(
            f"dense timeline compiler refused at M={M:,} (>= "
            f"{DENSE_REFUSE_M:,}): the (V={versions}, M={M:,}) "
            f"start/apply/staleness rows plus the (R, M) schedule would "
            f"materialize ~{est / 2**30:.2f} GiB host-side — run the "
            f"sparse stream (the fleet arm) instead")


def tiered(M: int) -> ClientPopulation:
    """4/5 fast + 1/5 slow clients — arrivals interleave across versions,
    so the pending set actually carries cross-version state."""
    n_slow = max(1, M // 5)
    return ClientPopulation(cohorts=(
        Cohort(name="fast", n=M - n_slow,
               delay=DelayModel(base=0.3, scale=0.3)),
        Cohort(name="slow", n=n_slow,
               delay=DelayModel(base=4.0, scale=0.5)),
    ))


# (result, seconds, peak_bytes) — the shared repro.obs.measure helper,
# so every benchmark's perf rows record the pair identically
_traced = measure


def bench_one(M: int, versions: int = VERSIONS, seed: int = 0) -> dict:
    sched = strag.make_schedule(seed, 8, population=tiered(M),
                                t_server=T_SERVER, t_comm=0.05)
    sfl = SFLConfig(n_clients=M, quorum=QUORUM,
                    staleness_discount=DISCOUNT, timeline="sparse")
    k_max, capacity = events.resolve_store_geometry(sfl)

    def dense():
        refuse_dense(M, versions)
        tl = events.compile_timeline(sched, versions, quorum=QUORUM,
                                     discount=DISCOUNT, tau=2)
        return int(tl.applied.sum())

    def sparse():
        st = events.TimelineStream(sched, versions, quorum=QUORUM,
                                   discount=DISCOUNT, taus=2, k_max=k_max,
                                   capacity=capacity)
        applied = 0
        while st.v < versions:          # streamed: chunks are dropped as
            applied += int(st.take(CHUNK).applied.sum())   # they're read
        return applied

    s_applied, s_sec, s_peak = _traced(sparse)
    row = {
        "clients": M, "versions": versions, "k_max": k_max,
        "ring_capacity": capacity,
        "sparse": {"sec": round(s_sec, 4), "peak_mb": round(s_peak / 2**20, 3),
                   "versions_per_s": round(versions / s_sec, 2),
                   "applied": s_applied},
    }
    try:
        d_applied, d_sec, d_peak = _traced(dense)
    except SystemExit as e:                    # M >= DENSE_REFUSE_M
        row["dense"] = {"refused": str(e)}    # measure() already stopped
        return row                            # tracemalloc on the raise
    row["dense"] = {"sec": round(d_sec, 4),
                    "peak_mb": round(d_peak / 2**20, 3),
                    "versions_per_s": round(versions / d_sec, 2),
                    "applied": d_applied}
    row["mem_reduction"] = round(d_peak / max(s_peak, 1), 2)
    row["speedup"] = round(d_sec / max(s_sec, 1e-9), 2)
    return row


def bench_fleet(M: int = FLEET_M, versions: int = VERSIONS,
                seed: int = 0) -> dict:
    """The compiler-only fleet arm: lazy schedule stream + sparse DES at
    M=1e6, nothing dense anywhere — the schedule is a SparseSchedule
    (per-cohort AvailRows, keyed on-demand delays), so peak memory is the
    O(M) scan state (busy flags, comm vector, idle index), not O(R·M) or
    O(V·M). Timing includes the schedule build: it is O(#cohorts)."""
    sfl = SFLConfig(n_clients=M, quorum=QUORUM,
                    staleness_discount=DISCOUNT, timeline="sparse")
    k_max, capacity = events.resolve_store_geometry(sfl)

    def fleet():
        sched = next(strag.make_schedule_stream(
            seed, 8, population=tiered(M), t_server=T_SERVER,
            t_comm=0.05, lazy=True))
        st = events.TimelineStream(sched, versions, quorum=QUORUM,
                                   discount=DISCOUNT, taus=2, k_max=k_max,
                                   capacity=capacity)
        applied = 0
        while st.v < versions:
            applied += int(st.take(CHUNK).applied.sum())
        return applied

    applied, sec, peak = _traced(fleet)
    base_sec = v7_extrapolated_sec(M)
    return {
        "clients": M, "versions": versions, "k_max": k_max,
        "ring_capacity": capacity, "sec": round(sec, 4),
        "peak_mb": round(peak / 2**20, 3),
        "versions_per_s": round(versions / sec, 2), "applied": applied,
        "v7_extrapolated_sec": round(base_sec, 4),
        "v7_extrapolated_versions_per_s": round(versions / base_sec, 2),
        "speedup_vs_v7": round(base_sec / sec, 2),
    }


# ---------------------------------------------------------------------------
# --smoke: the sparse == dense equivalence gate (CI)
# ---------------------------------------------------------------------------

SMOKE_POP = ClientPopulation(cohorts=(
    Cohort(name="fast", n=6, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=2, delay=DelayModel(base=4.0, scale=0.5),
           availability="markov-shared", p_dropout=0.12, p_recover=0.25),
))

_FIELDS = ("arrival_time", "client_id", "cohort_id", "round_of_origin",
           "staleness", "commit_idx", "start_mask", "apply_w",
           "staleness_m", "commit_times", "durations", "quorum_wait",
           "applied", "tau_per_version")


def smoke(seed: int = 0) -> None:
    # 1) compiler equivalence: densified sparse rows == dense rows,
    #    exactly, over quorum x discount x fleet (incl. the V=0 edge)
    fleets = [
        strag.make_schedule(seed, 8, population=SMOKE_POP,
                            t_server=T_SERVER, t_comm=0.05),
        strag.make_schedule(seed + 1, 8, 6, straggler_scale=2.0,
                            participation=0.5, t_server=0.1, t_comm=0.2),
    ]
    checked = 0
    for sched in fleets:
        for V in (0, 24):
            for quorum in (0, 5):
                for discount in (1.0, 0.5):
                    taus = 1 + (np.arange(V) % 3)
                    dense = events.compile_timeline(
                        sched, V, quorum=quorum, discount=discount, tau=taus)
                    got = events.compile_sparse_timeline(
                        sched, V, quorum=quorum, discount=discount,
                        tau=taus).densify()
                    for f in _FIELDS:
                        a, b = getattr(dense, f), getattr(got, f)
                        assert np.array_equal(a, b), \
                            f"sparse != dense on {f} (q={quorum}, " \
                            f"d={discount}, V={V})"
                    checked += 1
    print(f"smoke: densify(sparse) == dense on {checked} "
          f"(fleet, V, quorum, discount) grids — all fields exact")

    # 2) engine equivalence: sparse streamed execution reproduces the
    #    dense async loss trajectory (the acceptance bar is 1e-5; with a
    #    dyadic discount the two are bit-equal)
    from benchmarks.common import make_setup, run_mu_splitfed_result
    M = SMOKE_POP.n_clients
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    kw = dict(M=M, tau=2, cut=1, rounds=6, seed=seed, chunk_size=3,
              mode="async", algorithm="async_mu_splitfed",
              population=SMOKE_POP, t_server=T_SERVER, quorum=5,
              staleness_discount=DISCOUNT)
    d = run_mu_splitfed_result(cfg, params, ds, parts, key,
                               timeline="dense", **kw)
    s = run_mu_splitfed_result(cfg, params, ds, parts, key,
                               timeline="sparse", **kw)
    diff = float(np.max(np.abs(d.round_loss - s.round_loss)))
    assert diff <= 1e-5, f"sparse engine != dense async (max {diff:.2e})"
    assert np.array_equal(d.round_times, s.round_times), \
        "sparse round_times != dense commit durations"
    print(f"smoke: engine sparse == dense async trajectory "
          f"(max diff {diff:.1e} <= 1e-5) over {kw['rounds']} versions")

    # 3) cohort-index at scale: a fast M=1e4 Markov-fleet pass of the same
    #    exactness gate — the cohort-bucketed idle sets against the dense
    #    compiler's per-client reference scan, at a size where an O(M)
    #    candidate scan per version would already hurt
    M_big = 10_000
    n_slow = M_big // 5
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=M_big - n_slow,
               delay=DelayModel(base=0.3, scale=0.3),
               availability="markov", p_dropout=0.1, p_recover=0.3),
        Cohort(name="slow", n=n_slow, delay=DelayModel(base=4.0, scale=0.5),
               availability="markov-shared", p_dropout=0.12,
               p_recover=0.25),
    ))
    sched = strag.make_schedule(seed, 8, population=pop,
                                t_server=T_SERVER, t_comm=0.05)
    V = 12
    dense_tl = events.compile_timeline(sched, V, quorum=QUORUM,
                                       discount=DISCOUNT, tau=2)
    got = events.compile_sparse_timeline(sched, V, quorum=QUORUM,
                                         discount=DISCOUNT, tau=2).densify()
    for f in _FIELDS:
        assert np.array_equal(getattr(dense_tl, f), getattr(got, f)), \
            f"cohort-index != dense reference on {f} at M={M_big}"
    print(f"smoke: cohort-indexed DES == dense per-client reference at "
          f"M={M_big} (Markov + shared-chain fleet, {V} versions, all "
          f"fields exact)")

    # 4) O(K) subset staging == indexing the fleet-width gather, bit-exact
    #    (the engine's --loader subset path)
    from repro.data import (FederatedLoader, SyntheticLM,
                            dirichlet_partition)
    n_cl = 24
    ds = SyntheticLM(vocab_size=128, seq_len=16, seed=seed)
    parts = dirichlet_partition(np.arange(512) % 10, n_cl, alpha=0.5,
                                seed=seed)
    loader = FederatedLoader(ds, parts, batch_per_client=2, seed=seed)
    rng = np.random.default_rng(seed)
    for r in (0, 3):
        full = {k: np.asarray(v) for k, v in loader.round_batch(r).items()}
        ids = np.sort(rng.choice(n_cl, size=7, replace=False))
        sub = loader.subset_batch(r, ids)
        for k in full:
            assert np.array_equal(full[k][ids], sub[k]), \
                f"subset_batch != fleet gather on {k} (round {r})"
    print(f"smoke: loader subset staging == fleet-width gather "
          f"(bit-exact, {n_cl} clients, K=7 subsets)")

    # 5) the dense-compiler refusal actually fires with a size estimate
    try:
        refuse_dense(DENSE_REFUSE_M, VERSIONS)
    except SystemExit as e:
        assert "GiB" in str(e), "refusal message lost its size estimate"
    else:
        raise AssertionError("dense compiler accepted M >= DENSE_REFUSE_M")
    print("smoke: dense compiler refuses M >= "
          f"{DENSE_REFUSE_M:,} with a size estimate")

    # 6) static-analysis gate on the hot path: the event engine and the
    #    schedule sampler must pass repro.analysis clean (RNG discipline,
    #    host-sync, donation safety, ... — see analysis/baseline.json)
    from repro.analysis import check_clean
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    targets = [os.path.join(repo, "src", "repro", "core", "events.py"),
               os.path.join(repo, "src", "repro", "core", "straggler.py")]
    new, _ = check_clean(targets,
                         os.path.join(repo, "analysis", "baseline.json"))
    assert not new, "analyzer findings on the timeline hot path:\n" + \
        "\n".join(f.render() for f in new)
    print("smoke: repro.analysis clean on core/events.py + "
          "core/straggler.py (0 new findings)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: sparse == dense (compiler fields exact, "
                         "engine trajectory <= 1e-5); no json write")
    ap.add_argument("--versions", type=int, default=VERSIONS)
    ap.add_argument("--sizes", type=int, nargs="*", default=list(SIZES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="bench_timeline.json")
    ap.add_argument("--perf-out", default="perf_iterations.json")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke(seed=args.seed)
        return None

    results = []
    print(f"{'M':>8s} {'backend':>8s} {'sec':>8s} {'v/s':>9s} "
          f"{'peak_mb':>9s} {'mem_red':>8s} {'speedup':>8s}")
    for M in args.sizes:
        row = bench_one(M, versions=args.versions, seed=args.seed)
        if "refused" in row["dense"]:
            print(f"{M:8d} {'dense':>8s}  -- refused: (V, M) rows past "
                  f"M={DENSE_REFUSE_M:,} --")
        else:
            # bounded geometry (k_max << M) admits fewer starts than
            # dense — exact equality is the --smoke gate; sanity-bound it
            assert 0 < row["sparse"]["applied"] <= row["dense"]["applied"], \
                "sparse DES applied an impossible contribution count"
            print(f"{M:8d} {'dense':>8s} {row['dense']['sec']:8.3f} "
                  f"{row['dense']['versions_per_s']:9.1f} "
                  f"{row['dense']['peak_mb']:9.3f}")
        print(f"{M:8d} {'sparse':>8s} {row['sparse']['sec']:8.3f} "
              f"{row['sparse']['versions_per_s']:9.1f} "
              f"{row['sparse']['peak_mb']:9.3f}"
              + (f" {row['mem_reduction']:8.1f} {row['speedup']:8.1f}"
                 if "mem_reduction" in row else ""))
        results.append(row)

    fleet = bench_fleet(FLEET_M, versions=args.versions, seed=args.seed)
    print(f"\nfleet arm  M={fleet['clients']:,}  {fleet['sec']:.3f}s  "
          f"{fleet['versions_per_s']:.1f} v/s  peak "
          f"{fleet['peak_mb']:.1f} MB  ({fleet['speedup_vs_v7']:.1f}x the "
          f"v7 DES extrapolated to this M)")
    assert fleet["applied"] > 0, "fleet DES applied nothing"
    assert fleet["speedup_vs_v7"] >= 10.0, \
        (f"v8 gate: fleet arm {fleet['versions_per_s']} v/s is "
         f"{fleet['speedup_vs_v7']}x the v7 extrapolation "
         f"({fleet['v7_extrapolated_versions_per_s']} v/s) — need >= 10x")

    json.dump(results + [{"fleet": fleet}], open(args.out, "w"), indent=1)
    perf = {
        "variant": "v8", "bench": "bench_timeline",
        "quorum": QUORUM, "staleness_discount": DISCOUNT,
        "versions": args.versions, "t_server": T_SERVER,
        "rows": results,
        "fleet": fleet,
        "fleet_speedup_vs_v7_extrapolated": fleet["speedup_vs_v7"],
    }
    rows = (json.load(open(args.perf_out))
            if os.path.exists(args.perf_out) else [])
    rows.append(perf)
    json.dump(rows, open(args.perf_out, "w"), indent=1)
    print(f"appended v8 row to {args.perf_out} "
          f"({fleet['speedup_vs_v7']}x v7-extrapolated at "
          f"M={fleet['clients']:,})")
    return results


if __name__ == "__main__":
    main()
