"""Host timeline-compiler benchmark: dense (V, M) rows vs the sparse
streaming DES (core/events.py), at fleet sizes M ∈ {1e3, 1e4, 1e5}.

Measures, per backend and fleet size:
  * compile throughput (versions/s) — the dense compiler pays an O(M)
    Python start loop plus a full re-sort of the pending set per version;
    the sparse DES pays a vectorized candidate scan plus O((K+E) log M)
    heap work.
  * peak host memory (tracemalloc, which tracks numpy data since 1.22) —
    dense materializes (V, M) start/apply/staleness rows plus the O(E)
    event list; sparse streams (chunk, k_max) rows and keeps O(M) scan
    state, so the trace never materializes.

The acceptance gate for perf rung v7 is >= 10x peak-memory reduction at
M=1e5, K=64.

    PYTHONPATH=src python -m benchmarks.bench_timeline            # full
    PYTHONPATH=src python -m benchmarks.bench_timeline --smoke    # CI gate

--smoke is the sparse==dense equivalence gate: timeline fields exactly
equal after densifying (grid over quorum x discount x fleet), and the
engine's sparse loss trajectory within 1e-5 of the dense async path on a
tiered fleet (they are bit-equal here: same records in the same flatten
order, and dyadic discount weights normalize exactly).
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time
import tracemalloc

import numpy as np

from repro.configs import SFLConfig
from repro.core import events
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel

T_SERVER = 0.25
QUORUM = 64
DISCOUNT = 0.5
VERSIONS = 48
CHUNK = 8
SIZES = (1_000, 10_000, 100_000)


def tiered(M: int) -> ClientPopulation:
    """4/5 fast + 1/5 slow clients — arrivals interleave across versions,
    so the pending set actually carries cross-version state."""
    n_slow = max(1, M // 5)
    return ClientPopulation(cohorts=(
        Cohort(name="fast", n=M - n_slow,
               delay=DelayModel(base=0.3, scale=0.3)),
        Cohort(name="slow", n=n_slow,
               delay=DelayModel(base=4.0, scale=0.5)),
    ))


def _traced(fn):
    """(result, seconds, peak_bytes) of fn() under tracemalloc."""
    gc.collect()
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def bench_one(M: int, versions: int = VERSIONS, seed: int = 0) -> dict:
    sched = strag.make_schedule(seed, 8, population=tiered(M),
                                t_server=T_SERVER, t_comm=0.05)
    sfl = SFLConfig(n_clients=M, quorum=QUORUM,
                    staleness_discount=DISCOUNT, timeline="sparse")
    k_max, capacity = events.resolve_store_geometry(sfl)

    def dense():
        tl = events.compile_timeline(sched, versions, quorum=QUORUM,
                                     discount=DISCOUNT, tau=2)
        return int(tl.applied.sum())

    def sparse():
        st = events.TimelineStream(sched, versions, quorum=QUORUM,
                                   discount=DISCOUNT, taus=2, k_max=k_max,
                                   capacity=capacity)
        applied = 0
        while st.v < versions:          # streamed: chunks are dropped as
            applied += int(st.take(CHUNK).applied.sum())   # they're read
        return applied

    d_applied, d_sec, d_peak = _traced(dense)
    s_applied, s_sec, s_peak = _traced(sparse)
    row = {
        "clients": M, "versions": versions, "k_max": k_max,
        "ring_capacity": capacity,
        "dense": {"sec": round(d_sec, 4), "peak_mb": round(d_peak / 2**20, 3),
                  "versions_per_s": round(versions / d_sec, 2),
                  "applied": d_applied},
        "sparse": {"sec": round(s_sec, 4), "peak_mb": round(s_peak / 2**20, 3),
                   "versions_per_s": round(versions / s_sec, 2),
                   "applied": s_applied},
        "mem_reduction": round(d_peak / max(s_peak, 1), 2),
        "speedup": round(d_sec / max(s_sec, 1e-9), 2),
    }
    return row


# ---------------------------------------------------------------------------
# --smoke: the sparse == dense equivalence gate (CI)
# ---------------------------------------------------------------------------

SMOKE_POP = ClientPopulation(cohorts=(
    Cohort(name="fast", n=6, delay=DelayModel(base=0.3, scale=0.3)),
    Cohort(name="slow", n=2, delay=DelayModel(base=4.0, scale=0.5),
           availability="markov-shared", p_dropout=0.12, p_recover=0.25),
))

_FIELDS = ("arrival_time", "client_id", "cohort_id", "round_of_origin",
           "staleness", "commit_idx", "start_mask", "apply_w",
           "staleness_m", "commit_times", "durations", "quorum_wait",
           "applied", "tau_per_version")


def smoke(seed: int = 0) -> None:
    # 1) compiler equivalence: densified sparse rows == dense rows,
    #    exactly, over quorum x discount x fleet (incl. the V=0 edge)
    fleets = [
        strag.make_schedule(seed, 8, population=SMOKE_POP,
                            t_server=T_SERVER, t_comm=0.05),
        strag.make_schedule(seed + 1, 8, 6, straggler_scale=2.0,
                            participation=0.5, t_server=0.1, t_comm=0.2),
    ]
    checked = 0
    for sched in fleets:
        for V in (0, 24):
            for quorum in (0, 5):
                for discount in (1.0, 0.5):
                    taus = 1 + (np.arange(V) % 3)
                    dense = events.compile_timeline(
                        sched, V, quorum=quorum, discount=discount, tau=taus)
                    got = events.compile_sparse_timeline(
                        sched, V, quorum=quorum, discount=discount,
                        tau=taus).densify()
                    for f in _FIELDS:
                        a, b = getattr(dense, f), getattr(got, f)
                        assert np.array_equal(a, b), \
                            f"sparse != dense on {f} (q={quorum}, " \
                            f"d={discount}, V={V})"
                    checked += 1
    print(f"smoke: densify(sparse) == dense on {checked} "
          f"(fleet, V, quorum, discount) grids — all fields exact")

    # 2) engine equivalence: sparse streamed execution reproduces the
    #    dense async loss trajectory (the acceptance bar is 1e-5; with a
    #    dyadic discount the two are bit-equal)
    from benchmarks.common import make_setup, run_mu_splitfed_result
    M = SMOKE_POP.n_clients
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    kw = dict(M=M, tau=2, cut=1, rounds=6, seed=seed, chunk_size=3,
              mode="async", algorithm="async_mu_splitfed",
              population=SMOKE_POP, t_server=T_SERVER, quorum=5,
              staleness_discount=DISCOUNT)
    d = run_mu_splitfed_result(cfg, params, ds, parts, key,
                               timeline="dense", **kw)
    s = run_mu_splitfed_result(cfg, params, ds, parts, key,
                               timeline="sparse", **kw)
    diff = float(np.max(np.abs(d.round_loss - s.round_loss)))
    assert diff <= 1e-5, f"sparse engine != dense async (max {diff:.2e})"
    assert np.array_equal(d.round_times, s.round_times), \
        "sparse round_times != dense commit durations"
    print(f"smoke: engine sparse == dense async trajectory "
          f"(max diff {diff:.1e} <= 1e-5) over {kw['rounds']} versions")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: sparse == dense (compiler fields exact, "
                         "engine trajectory <= 1e-5); no json write")
    ap.add_argument("--versions", type=int, default=VERSIONS)
    ap.add_argument("--sizes", type=int, nargs="*", default=list(SIZES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="bench_timeline.json")
    ap.add_argument("--perf-out", default="perf_iterations.json")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke(seed=args.seed)
        return None

    results = []
    print(f"{'M':>8s} {'backend':>8s} {'sec':>8s} {'v/s':>9s} "
          f"{'peak_mb':>9s} {'mem_red':>8s} {'speedup':>8s}")
    for M in args.sizes:
        row = bench_one(M, versions=args.versions, seed=args.seed)
        # bounded geometry (k_max << M) admits fewer starts than dense —
        # exact equality is the --smoke gate; here just sanity-bound it
        assert 0 < row["sparse"]["applied"] <= row["dense"]["applied"], \
            "sparse DES applied an impossible contribution count"
        for b in ("dense", "sparse"):
            print(f"{M:8d} {b:>8s} {row[b]['sec']:8.3f} "
                  f"{row[b]['versions_per_s']:9.1f} "
                  f"{row[b]['peak_mb']:9.3f}"
                  + (f" {row['mem_reduction']:8.1f} {row['speedup']:8.1f}"
                     if b == "sparse" else ""))
        results.append(row)

    big = results[-1]
    json.dump(results, open(args.out, "w"), indent=1)
    perf = {
        "variant": "v7", "bench": "bench_timeline",
        "quorum": QUORUM, "staleness_discount": DISCOUNT,
        "versions": args.versions, "t_server": T_SERVER,
        "rows": results,
        "mem_reduction_at_max_M": big["mem_reduction"],
        "compile_speedup_at_max_M": big["speedup"],
    }
    rows = (json.load(open(args.perf_out))
            if os.path.exists(args.perf_out) else [])
    rows.append(perf)
    json.dump(rows, open(args.perf_out, "w"), indent=1)
    print(f"\nappended v7 row to {args.perf_out} "
          f"(mem reduction {big['mem_reduction']}x at M={big['clients']})")
    return results


if __name__ == "__main__":
    main()
