"""Microbenchmark: sequential vs fused batched seed-replay (ladder v3→v4).

Replays N records (the N = M·τ·P of one seed-replay aggregation) into a
synthetic parameter tree through both engines:

  scan   zo.replay_updates        — lax.scan, one full parameter-sized HBM
                                    read+write sweep PER RECORD;
  fused  zo.fused_replay_updates  — all N counter-gaussian contributions
                                    accumulated per leaf before x is
                                    touched: one sweep total.

Reports wall time and HBM traffic per record, both analytic
(read+write = 2·4·d bytes per sweep) and as measured on the lowered HLO by
launch/hlo_analysis (which expands while-loop trip counts — the same
analysis the perf ladder uses).

    PYTHONPATH=src python -m benchmarks.bench_replay --d 1048576 --n 32
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zo
from repro.launch.hlo_analysis import analyze_compiled
from repro.obs import measure


def make_tree(d: int, key):
    """A few unevenly-shaped f32 leaves totalling ~d elements."""
    sizes = [d // 2, d // 4, d // 8, d - d // 2 - d // 4 - d // 8]
    ks = jax.random.split(key, len(sizes))
    return {f"w{i}": jax.random.normal(k, (max(s, 1),), jnp.float32)
            for i, (s, k) in enumerate(zip(sizes, ks))}


def timed(fn, *args, reps=3):
    """(ms_per_rep, host_peak_bytes) via the shared obs.measure helper —
    same (seconds, peak_bytes) pair every benchmark row records."""
    jax.block_until_ready(fn(*args))            # compile

    def body():
        for _ in range(reps):
            out = fn(*args)
        return jax.block_until_ready(out)

    m = measure(body)
    return m.seconds / reps * 1e3, m.peak_bytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=1 << 20,
                    help="total parameter elements")
    ap.add_argument("--n", type=int, default=32,
                    help="records to replay (M·τ·P of one aggregation)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    params = make_tree(args.d, key)
    d = sum(x.size for x in jax.tree.leaves(params))
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(args.n))
    coeffs = jnp.asarray(
        (np.random.default_rng(0).normal(size=args.n) * 1e-3
         ).astype(np.float32))

    scan_fn = jax.jit(lambda p, k, c: zo.replay_updates(p, k, c, "counter"))
    fused_fn = jax.jit(
        lambda p, k, c: zo.fused_replay_updates(p, k, c, "counter"))

    # correctness gate before timing
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(scan_fn(params, keys, coeffs)),
        jax.tree.leaves(fused_fn(params, keys, coeffs))))
    assert diff <= 1e-5, f"fused/scan diverge: {diff}"

    rows = {}
    sweep_bytes = 2 * 4 * d                       # one f32 read+write sweep
    for name, fn, sweeps in (("scan_v3", scan_fn, args.n),
                             ("fused_v4", fused_fn, 1)):
        hlo = analyze_compiled(fn.lower(params, keys, coeffs).compile())
        ms, peak = timed(fn, params, keys, coeffs, reps=args.reps)
        rows[name] = {
            "wall_ms": round(ms, 3),
            "host_peak_mb": round(peak / 2**20, 3),
            "analytic_hbm_bytes_per_record": sweep_bytes * sweeps / args.n,
            "hlo_hbm_bytes_per_record": hlo["expanded_hbm_bytes"] / args.n,
        }
    fused_hlo = rows["fused_v4"]["hlo_hbm_bytes_per_record"]
    report = {"d": d, "n_records": args.n, "max_abs_diff": diff,
              "per_path": rows,
              "hbm_reduction_analytic": args.n,   # scan sweeps N×, fused 1×
              # the HLO parser skips call-wrapped fusion interiors, so tiny
              # programs can report 0 fused bytes — guard the ratio
              "hbm_reduction_hlo": (
                  rows["scan_v3"]["hlo_hbm_bytes_per_record"] / fused_hlo
                  if fused_hlo > 0 else None)}
    print(json.dumps(report, indent=1))
    if args.out:
        json.dump(report, open(args.out, "w"), indent=1)
    return report


if __name__ == "__main__":
    main()
