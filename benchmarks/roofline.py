"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Reads the dry-run JSONs (per-device expanded FLOPs / HBM bytes / collective
bytes from the compiled SPMD module) and derives the three roofline terms
per (arch × shape × mesh):

    compute    = flops_per_chip / PEAK_FLOPS           [s]
    memory     = hbm_bytes_per_chip / HBM_BW           [s]
    collective = collective_bytes_per_chip / LINK_BW   [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS (the "useful work" yardstick):
  * train cells: the prescribed 6·N·D with N = trainable params (N_active
    for MoE) and D = tokens per step — the first-order-training convention.
    ZO training does 2·N·D per forward and (2τP+2) server + 3 client
    forwards per round, so we ALSO report zo_model_flops (the
    algorithm-native count); ratio_hlo uses zo_model_flops (catches real
    redundancy rather than the ZO-vs-FO protocol difference).
  * serve cells: 2·N_active·D.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline \
        --inputs dryrun_single.json dryrun_multi.json --md
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

TAU = 2                      # dry-run default
P_PERT = 1
M_CLIENTS = 16


def _cfg(arch):
    from repro.configs import get_config
    return get_config(arch)


def active_params(arch: str) -> Dict[str, float]:
    """(total, active) param counts; active = shared + top_k experts only."""
    import jax
    from repro.models import init_params, split_dims
    cfg = _cfg(arch)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    size = lambda t: sum(int(_np_prod(x.shape)) for x in jax.tree.leaves(t))
    total = size(shapes)
    active = total
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        k = cfg.moe.top_k
        expert_leaves = 0
        units = shapes["units"]
        for bkey, blk in units.items():
            ffn = blk.get("ffn", {})
            for nm in ("wi", "wg", "wo"):
                if nm in ffn and len(ffn[nm].shape) == 4:  # (u, E, D, F)
                    expert_leaves += int(_np_prod(ffn[nm].shape))
        active = total - expert_leaves + expert_leaves * k / E
    d_c, d_s = split_dims(cfg, cfg.default_cut_units)
    return {"total": total, "active": active, "d_c": d_c, "d_s": d_s}


def _np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def model_flops(arch: str, shape_name: str, rec: dict) -> Dict[str, float]:
    from repro.configs import SHAPES_BY_NAME
    sh = SHAPES_BY_NAME[shape_name]
    ap = active_params(arch)
    cfg = _cfg(arch)
    frac_active = ap["active"] / ap["total"]
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        fo = 6.0 * ap["active"] * tokens
        tok_per_client = tokens / M_CLIENTS
        fwd = 2.0 * frac_active
        zo = M_CLIENTS * tok_per_client * (
            3 * fwd * ap["d_c"] + (2 * TAU * P_PERT + 2) * fwd * ap["d_s"])
        return {"fo_6nd": fo, "zo_native": zo}
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
    else:
        tokens = sh.global_batch * 1
    f = 2.0 * ap["active"] * tokens
    return {"fo_6nd": f, "zo_native": f}


def analyze(records: List[dict], n_chips_by_mesh=None) -> List[dict]:
    n_chips_by_mesh = n_chips_by_mesh or {"16x16": 256, "2x16x16": 512}
    rows = []
    for r in records:
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"),
                         "status": r.get("status")})
            continue
        n_chips = n_chips_by_mesh.get(r["mesh"], 256)
        coll = r["collectives"]
        flops_chip = coll["expanded_dot_flops"]     # per-device SPMD module
        # operand+result accounting counts each producer->consumer edge at
        # both endpoints; halve to approximate actual read+write traffic.
        hbm_chip = coll["expanded_hbm_bytes"] / 2.0
        coll_chip = coll["total_bytes"]
        t_c = flops_chip / PEAK_FLOPS
        t_m = hbm_chip / HBM_BW
        t_x = coll_chip / LINK_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(r["arch"], r["shape"], r)
        useful = mf["zo_native"] if r["shape"] == "train_4k" else mf["fo_6nd"]
        ratio = useful / (flops_chip * n_chips) if flops_chip else 0.0
        bound = max(t_c, t_m, t_x)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "plan": r.get("plan", {}),
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "roofline_fraction": (t_c / bound) if bound else 0.0,
            "model_flops_6nd": mf["fo_6nd"],
            "model_flops_zo": mf["zo_native"],
            "hlo_flops_global": flops_chip * n_chips,
            "useful_ratio": ratio,
            "per_chip_hbm_gib": hbm_chip / 2**30,
            "per_chip_coll_gib": coll_chip / 2**30,
        })
    return rows


def to_markdown(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | {r.get('status')} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
            f"| {r['t_collective_s']:.3g} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--inputs", nargs="+",
                    default=["dryrun_single.json", "dryrun_multi.json"])
    ap.add_argument("--out", default="roofline.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    records = []
    for f in args.inputs:
        try:
            records.extend(json.load(open(f)))
        except FileNotFoundError:
            print(f"[roofline] missing {f} (run the dry-run first)")
    rows = analyze(records)
    json.dump(rows, open(args.out, "w"), indent=1)
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            if r.get("status") == "ok":
                print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                      f"C={r['t_compute_s']:.3g}s M={r['t_memory_s']:.3g}s "
                      f"X={r['t_collective_s']:.3g}s -> {r['dominant']}")
    print(f"[roofline] {sum(1 for r in rows if r.get('status')=='ok')} rows "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
