"""Paper Fig. 2: convergence vs wall-clock under stragglers, MU-SplitFed
vs vanilla SplitFed vs GAS-like async. Also --verify-eq12.

Per-round client compute times ~ base·(1+Exp(scale)) (paper §5 protocol);
loss curves come from real training rounds; wall-clock from the straggler
simulator's per-algorithm round-time model.

    PYTHONPATH=src python -m benchmarks.fig2_straggler [--rounds 30]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import batch_fn_for, make_setup
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag

T_SERVER = 0.25
# GAS generates synthetic activations each round; the paper (§5) observes
# this "scales poorly with the increasing size of the label" — for LM-sized
# outputs it dominates, which is why GAS underperforms there.
T_GEN = 2.0


def run(rounds=30, M=4, tau=4, scale=3.0, seed=0):
    cfg, params, ds, parts, key = make_setup(M=M, seed=seed)
    # one shared system-model trace: all three algorithms see the same
    # delays; the default knobs give all-ones masks (full participation, no
    # deadline — the Fig. 2 protocol) and GAS derives its freshness mask
    # from the per-round median delay
    sched = strag.make_schedule(seed, rounds, M, straggler_scale=scale,
                                t_server=T_SERVER, t_gen=T_GEN)
    batch_fn = batch_fn_for(ds, parts, 2, seed)

    curves = {}
    for algo in ("mu_splitfed", "vanilla", "gas"):
        sfl = SFLConfig(n_clients=M, tau=tau if algo == "mu_splitfed" else 1,
                        cut_units=1, lr_server=5e-3, lr_client=1e-3,
                        lr_global=1.0)
        res = engine.run_rounds(algo, cfg, sfl, params, batch_fn, sched, key,
                                rounds=rounds,
                                **({"fresh": "median"} if algo == "gas"
                                   else {}))
        losses = [float(x) for x in res.metrics["loss"].mean(1)]
        curves[algo] = {"wall": list(np.cumsum(res.round_times)),
                        "loss": losses}
    return curves


def verify_eq12(scale=3.0, M=8, T0=400, seed=0):
    """Eq. 12: with τ = t_straggler/t_server the total time is T0·t_server,
    independent of straggler delay — sweep the delay scale and check."""
    rows = []
    for s in (0.5, 1.0, 2.0, 4.0, 8.0):
        rng = np.random.default_rng(seed)
        delays = strag.DelayModel(base=1.0, scale=s).sample(rng, M, T0)
        masks = np.ones_like(delays, np.float32)
        t_strag = float(delays.max(1).mean())
        tau = strag.plan_tau(t_strag, T_SERVER)
        t_mu = strag.simulate_total_time("mu_splitfed", delays, masks,
                                         T_SERVER, tau,
                                         rounds_needed=max(T0 // tau, 1))
        t_va = strag.simulate_total_time("vanilla", delays, masks, T_SERVER,
                                         1, rounds_needed=T0)
        rows.append({"scale": s, "t_straggler": t_strag, "tau_planned": tau,
                     "t_mu": t_mu, "t_vanilla": t_va,
                     "t_mu_over_T0_tserver": t_mu / (T0 * T_SERVER)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--verify-eq12", action="store_true")
    ap.add_argument("--out", default="bench_fig2.json")
    args = ap.parse_args(argv)
    if args.verify_eq12:
        rows = verify_eq12()
        print(f"{'scale':>6s} {'t_strag':>8s} {'tau*':>5s} {'total_mu':>9s} "
              f"{'total_vanilla':>13s} {'mu/(T0·ts)':>10s}")
        for r in rows:
            print(f"{r['scale']:6.1f} {r['t_straggler']:8.2f} "
                  f"{r['tau_planned']:5d} {r['t_mu']:9.1f} "
                  f"{r['t_vanilla']:13.1f} {r['t_mu_over_T0_tserver']:10.2f}")
        json.dump(rows, open(args.out, "w"))
        return rows
    curves = run(rounds=args.rounds)
    for algo, c in curves.items():
        print(f"{algo:12s} final_loss={c['loss'][-1]:.4f} "
              f"total_time={c['wall'][-1]:.1f}")
    json.dump(curves, open(args.out, "w"))
    return curves


if __name__ == "__main__":
    main()
