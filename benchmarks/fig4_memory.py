"""Paper Fig. 4: peak CLIENT-side memory for fine-tuning an LLM —
FedAvg vs FedAvg+LoRA vs MU-SplitFed.

Two measurements:
  1. analytic bytes model at the paper's scale (OPT-1.3B), mirroring the
     paper's 8.02 / 5.64 / 1.05 GB comparison;
  2. measured: XLA memory_analysis of the jitted client-side step on the
     smoke config (ground truth for the model's shape).

Client memory models (bf16 weights, f32 optimizer/grads where held):
  FedAvg      : full weights + grads + Adam(m,v) + activations(backward)
  FedLoRA     : full weights (frozen) + adapter grads/moments + activations
  MU-SplitFed : CLIENT PREFIX weights only + NO grads/optimizer (ZO) +
                forward-only activations of the prefix
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import client_forward, init_params, loss_fn, split_dims, split_params, untie_params

GiB = 2 ** 30


def analytic(arch="paper-opt-1.3b", cut=2, batch=32, seq=128,
             lora_rank=16) -> dict:
    """Half-precision client training (fp16 weights/grads/Adam states —
    the setting that reproduces the paper's 8.02 GB for OPT-1.3B: ~6 bytes
    of persistent state per trainable parameter + activations)."""
    cfg = get_config(arch)
    d_c, d_s = split_dims(cfg, cut)
    d = d_c + d_s
    act_per_layer = batch * seq * cfg.d_model * 2          # bf16
    # backward training keeps ~all layer activations (no remat on clients)
    acts_full = act_per_layer * cfg.n_layers * 6           # qkv/ffn temps
    acts_prefix = act_per_layer * (cut * cfg.unit_len) * 2  # forward-only
    lora_params = cfg.n_layers * 2 * (2 * cfg.d_model * lora_rank)
    fedavg = d * (2 + 2 + 2) + acts_full     # fp16 w + g + Adam(m,v fp16)
    fedlora = d * 2 + lora_params * (2 + 4) + acts_full
    mu = d_c * 2 + acts_prefix               # ZO: no grads, no optimizer
    return {"fedavg_gib": fedavg / GiB, "fedlora_gib": fedlora / GiB,
            "mu_splitfed_client_gib": mu / GiB,
            "paper_reported": {"fedavg": 8.02, "fedlora": 5.64,
                               "mu_splitfed": 1.05},
            "d": d, "d_c": d_c}


def measured_smoke(arch="paper-opt-1.3b", batch=4, seq=64) -> dict:
    """XLA memory_analysis of (a) full-model grad step vs (b) client
    forward, on the smoke config."""
    cfg = get_config(arch, smoke=True)
    params = untie_params(cfg, init_params(cfg, jax.random.PRNGKey(0)))
    batch_d = {"tokens": jnp.zeros((batch, seq), jnp.int32),
               "labels": jnp.zeros((batch, seq), jnp.int32)}

    grad_step = jax.jit(jax.grad(lambda p, b: loss_fn(cfg, p, b)))
    m1 = grad_step.lower(params, batch_d).compile().memory_analysis()
    cp, _ = split_params(cfg, params, cfg.default_cut_units)
    fwd = jax.jit(lambda p, b: client_forward(cfg, p, b))
    m2 = fwd.lower(cp, batch_d).compile().memory_analysis()

    def tot(m):
        return (m.argument_size_in_bytes + m.output_size_in_bytes
                + m.temp_size_in_bytes)
    return {"fedavg_grad_step_mib": tot(m1) / 2**20,
            "mu_client_fwd_mib": tot(m2) / 2**20,
            "ratio": tot(m1) / max(tot(m2), 1)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_fig4.json")
    ap.add_argument("--skip-measured", action="store_true")
    args = ap.parse_args(argv)
    res = {"analytic_opt_1_3b": analytic()}
    if not args.skip_measured:
        res["measured_smoke"] = measured_smoke()
    a = res["analytic_opt_1_3b"]
    print(f"{'method':>12s} {'analytic GiB':>13s} {'paper GB':>9s}")
    for k, pk in (("fedavg", "fedavg"), ("fedlora", "fedlora"),
                  ("mu_splitfed_client", "mu_splitfed")):
        print(f"{pk:>12s} {a[k + '_gib']:13.2f} "
              f"{a['paper_reported'][pk]:9.2f}")
    if "measured_smoke" in res:
        m = res["measured_smoke"]
        print(f"measured smoke: FO grad step {m['fedavg_grad_step_mib']:.1f}"
              f" MiB vs ZO client fwd {m['mu_client_fwd_mib']:.1f} MiB "
              f"(x{m['ratio']:.1f})")
    json.dump(res, open(args.out, "w"))
    return res


if __name__ == "__main__":
    main()
