"""The paper's LLM scenario (§5, OPT-1.3B on SST-2): split fine-tuning of a
transformer LM on a sentiment task with ZO updates and a cut-layer × τ
choice guided by Cor. 4.2 — here at smoke scale for CPU.

The client holds only the embedding + first units (1.05 GB at the paper's
scale — see benchmarks/fig4_memory.py); the server fine-tunes the deep
suffix with τ unbalanced ZO steps per round. The metric is label-token
accuracy (the SST-2 stand-in verbalizes the label as the final token).

    PYTHONPATH=src python examples/llm_split_finetune.py [--tau 2] [--cut 1]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import engine, make_schedule, theory
from repro.data.synthetic import SyntheticSentiment
from repro.models import init_params, logits_fn, untie_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--cut", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--population", default="",
                    help="tiered fleet spec, e.g. 'tiered:2x1.0,2x0.5' "
                         "(overrides --clients)")
    ap.add_argument("--straggler-scale", type=float, default=0.0,
                    help="shared exponential jitter for every cohort")
    args = ap.parse_args()

    from repro.core.straggler import ClientPopulation, parse_population
    population = (parse_population(args.population,
                                   straggler_scale=args.straggler_scale)
                  if args.population else None)
    if population is not None:
        args.clients = population.n_clients
        print(f"fleet: {population.describe()}")

    cfg = get_config("paper-opt-1.3b", smoke=True).replace(dtype="float32")
    best_cut, _ = theory.plan_cut(cfg, args.tau)
    print(f"theory cut planner: d_c=sqrt(d/tau) suggests cut={best_cut} "
          f"for tau={args.tau} (using --cut {args.cut})")
    sfl = SFLConfig(n_clients=args.clients, tau=args.tau, cut_units=args.cut,
                    lr_server=5e-3, lr_client=1e-3, lr_global=1.0,
                    population=population)

    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    ds = SyntheticSentiment(vocab_size=cfg.vocab_size, seq_len=32, seed=0)

    def eval_acc(params, n=32):
        b = ds.batch(np.arange(10_000, 10_000 + n))
        logits = logits_fn(cfg, params, {"tokens": jnp.asarray(b["tokens"])})
        return ds.accuracy(np.asarray(logits[:, -2].astype(jnp.float32)),
                           b["class"])

    def batch_fn(r):
        rows = [ds.batch(np.arange(r * 64 + m * 16, r * 64 + m * 16 + 4))
                for m in range(args.clients)]
        return {k2: np.stack([x[k2] for x in rows])
                for k2 in ("tokens", "labels")}

    def on_chunk(info, p, s):
        # evals land exactly on the chunk boundaries (every 5 rounds)
        print(f"round {info.stop:3d}  loss "
              f"{float(info.metrics['loss'].mean()):.4f}  "
              f"label acc {eval_acc(p):.2f}")

    sched = make_schedule(0, args.rounds,
                          population=ClientPopulation.resolve(sfl))
    print(f"initial label accuracy: {eval_acc(params):.2f}")
    engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched, key,
                      rounds=args.rounds, chunk_size=5,
                      chunk_callback=on_chunk)


if __name__ == "__main__":
    main()
