"""End-to-end driver: train a ~100M-parameter LM with MU-SplitFed for a few
hundred rounds, with checkpointing/restart and straggler simulation — the
full production loop at a single-host scale.

Full run (a few hundred rounds; hours on CPU, minutes on real chips):
    PYTHONPATH=src python examples/train_100m.py --rounds 300

CI-scale smoke (verifies the same code path end to end):
    PYTHONPATH=src python examples/train_100m.py --rounds 3 --tiny
"""
import argparse
import time

import jax
import numpy as np

from repro.ckpt import Checkpointer, latest_step
from repro.configs import SFLConfig, get_config
from repro.core import engine
from repro.core import straggler as strag
from repro.data import FederatedLoader, SyntheticLM, dirichlet_partition
from repro.models import init_params, param_count, untie_params


def model_100m():
    """~100M dense LM (GQA, SwiGLU) built from the config system."""
    return get_config("olmo-1b").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
        vocab_size=32768, max_seq_len=1024, norm_type="rmsnorm",
        tie_embeddings=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1, help="per-client")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale model (CI)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--straggler-scale", type=float, default=2.0)
    ap.add_argument("--population", default="",
                    help="tiered fleet spec, e.g. 'tiered:2x1.0,2x0.25' "
                         "(overrides --clients)")
    ap.add_argument("--adaptive-tau", action="store_true",
                    help="re-plan tau at chunk boundaries (AdaptiveTau)")
    args = ap.parse_args()

    population = (strag.parse_population(
        args.population, straggler_scale=args.straggler_scale)
        if args.population else None)
    n_clients = population.n_clients if population else args.clients

    cfg = (get_config("olmo-1b", smoke=True) if args.tiny else model_100m())
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    print(f"model: {param_count(params)/1e6:.1f}M params  "
          f"clients={n_clients} tau={args.tau}")
    if population is not None:
        print(f"fleet: {population.describe()}")

    sfl = SFLConfig(n_clients=n_clients, tau=args.tau, cut_units=2,
                    lr_server=2e-3, lr_client=5e-4, lr_global=1.0,
                    straggler_rate=args.straggler_scale,
                    population=population)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
    parts = dirichlet_partition(np.arange(8192) % 16, n_clients,
                                alpha=0.5, seed=0)
    loader = FederatedLoader(ds, parts, args.batch, seed=0)

    controller = (engine.AdaptiveTau(tau_max=16, quantize=True)
                  if args.adaptive_tau else None)
    ck = Checkpointer(args.ckpt_dir, keep=3)
    start, state = 0, None
    if latest_step(args.ckpt_dir) is not None:
        # engine bundles algorithm state with params, so stateful
        # algorithms resume exactly; mu_splitfed is stateless and
        # restores params alone. Controller decisions (adapted tau/lr)
        # and EMA state replay from the checkpoint metadata.
        params, state, meta = engine.restore_run(
            ck, "mu_splitfed", cfg, sfl, params, loader.round_batch)
        sfl = engine.apply_resume_overrides(sfl, meta, controller)
        start = meta["step"] + 1
        print(f"[resume] round {start} (tau={sfl.tau})")

    # the full system model — per-cohort delays and availability — as
    # precomputed data; the engine runs the rounds as fused on-device
    # scans with checkpoints at chunk boundaries
    sched = strag.make_schedule(0, args.rounds,
                                population=strag.ClientPopulation.resolve(sfl),
                                t_server=0.1)
    t0 = time.time()
    wall = strag.WallClock()

    def on_chunk(info, p, s):
        for i, r in enumerate(range(info.start, info.stop)):
            wall.tick(info.round_times[i])
            if r % 10 == 0 or r == args.rounds - 1:
                print(f"round {r:4d}  loss "
                      f"{float(info.metrics['loss'][i].mean()):.4f}"
                      f"  wall {time.time()-t0:7.1f}s  sim {wall.t:8.1f}s")

    engine.run_rounds("mu_splitfed", cfg, sfl, params, loader.round_batch,
                      sched, key, rounds=args.rounds, start_round=start,
                      state=state, chunk_size=5, checkpointer=ck,
                      ckpt_every=25, chunk_callback=on_chunk,
                      controller=controller)
    print("done.")


if __name__ == "__main__":
    main()
