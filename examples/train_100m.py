"""End-to-end driver: train a ~100M-parameter LM with MU-SplitFed for a few
hundred rounds, with checkpointing/restart and straggler simulation — the
full production loop at a single-host scale.

Full run (a few hundred rounds; hours on CPU, minutes on real chips):
    PYTHONPATH=src python examples/train_100m.py --rounds 300

CI-scale smoke (verifies the same code path end to end):
    PYTHONPATH=src python examples/train_100m.py --rounds 3 --tiny
"""
import argparse
import time

import jax
import numpy as np

from repro.ckpt import Checkpointer, latest_step
from repro.configs import SFLConfig, get_config
from repro.core import engine
from repro.core import straggler as strag
from repro.data import FederatedLoader, SyntheticLM, dirichlet_partition
from repro.models import init_params, param_count, untie_params


def model_100m():
    """~100M dense LM (GQA, SwiGLU) built from the config system."""
    return get_config("olmo-1b").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
        vocab_size=32768, max_seq_len=1024, norm_type="rmsnorm",
        tie_embeddings=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1, help="per-client")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale model (CI)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--straggler-scale", type=float, default=2.0)
    args = ap.parse_args()

    cfg = (get_config("olmo-1b", smoke=True) if args.tiny else model_100m())
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    print(f"model: {param_count(params)/1e6:.1f}M params  "
          f"clients={args.clients} tau={args.tau}")

    sfl = SFLConfig(n_clients=args.clients, tau=args.tau, cut_units=2,
                    lr_server=2e-3, lr_client=5e-4, lr_global=1.0)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
    parts = dirichlet_partition(np.arange(8192) % 16, args.clients,
                                alpha=0.5, seed=0)
    loader = FederatedLoader(ds, parts, args.batch, seed=0)

    ck = Checkpointer(args.ckpt_dir, keep=3)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        params, meta = ck.restore(params)
        start = meta["step"] + 1
        print(f"[resume] round {start}")

    # the full system model precomputed as data; the engine runs the rounds
    # as fused on-device scans with checkpoints at chunk boundaries
    sched = strag.make_schedule(0, args.rounds, args.clients,
                                straggler_scale=args.straggler_scale,
                                t_server=0.1)
    t0 = time.time()
    wall = strag.WallClock()

    def on_chunk(info, p, s):
        for i, r in enumerate(range(info.start, info.stop)):
            wall.tick(info.round_times[i])
            if r % 10 == 0 or r == args.rounds - 1:
                print(f"round {r:4d}  loss "
                      f"{float(info.metrics['loss'][i].mean()):.4f}"
                      f"  wall {time.time()-t0:7.1f}s  sim {wall.t:8.1f}s")

    engine.run_rounds("mu_splitfed", cfg, sfl, params, loader.round_batch,
                      sched, key, rounds=args.rounds, start_round=start,
                      chunk_size=5, checkpointer=ck, ckpt_every=25,
                      chunk_callback=on_chunk)
    print("done.")


if __name__ == "__main__":
    main()
