"""Straggler resilience (paper Fig. 2 + Eq. 12 scenario) on a
HETEROGENEOUS fleet: a tiered ClientPopulation — fast clients plus a
much slower tier with bursty Markov availability — trained four ways
under the same simulated schedule, one per execution mode:

  vanilla       τ=1, sync barrier: every round serializes on the
                straggler wait
  static τ*     τ planned once from the observed mean delay
                (Eq. 12: τ* = t_straggler / t_server, capped)
  adaptive τ    engine.AdaptiveTau re-plans τ at every chunk boundary
                from the straggler gap it just observed — τ rides up
                when the slow tier is present and collapses during
                dropout bursts, so no round over- or under-buys
                server steps
  semi-async    mode='async' (core/events.py): the barrier itself goes —
                the server commits a version as soon as a quorum of K
                contributions arrives, and the slow tier's late work
                folds into a later commit, staleness-discounted through
                the fused seed-replay path

Learning rates follow Thm 4.1's coupling (η_s·τ held constant). The whole
run goes through the unified engine: the population samples one schedule,
rounds execute as fused on-device scans (sync) or as scans over the
compiled event timeline (semi-async), and the controller hooks the chunk
boundaries.

    PYTHONPATH=src python examples/straggler_resilience.py
"""
import jax
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import engine
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params

T_SERVER, ROUNDS, ETA = 0.5, 24, 8e-3
POP = ClientPopulation(cohorts=(
    Cohort(name="fast", n=2, delay=DelayModel(base=0.5, scale=0.5)),
    Cohort(name="slow", n=2, delay=DelayModel(base=3.0, scale=1.0),
           availability="markov", p_dropout=0.15, p_recover=0.25),
))
M = POP.n_clients

cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
key = jax.random.PRNGKey(0)
params0 = untie_params(cfg, init_params(cfg, key))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
parts = dirichlet_partition(np.arange(256) % 8, M, alpha=0.5)

sched = strag.make_schedule(0, ROUNDS, population=POP, t_server=T_SERVER)
t_straggler = float(sched.delays.max(1).mean())
tau_star = strag.plan_tau(t_straggler, T_SERVER, tau_max=8)
print(f"fleet: {POP.describe()}")
print(f"mean straggler time {t_straggler:.2f}s, t_server {T_SERVER}s "
      f"-> one-shot planned tau* = {tau_star} (capped at 8)\n")

QUORUM = 2                       # semi-async: commit on the 2 fastest of 4
arms = (("vanilla(tau=1)", "mu_splitfed", "scan", 1, None, 0),
        (f"static(tau={tau_star})", "mu_splitfed", "scan", tau_star, None, 0),
        ("adaptive", "mu_splitfed", "scan", 1,
         engine.AdaptiveTau(tau_max=8, quantize=True), 0),
        (f"semi-async(K={QUORUM})", "async_mu_splitfed", "async", tau_star,
         None, QUORUM))
for name, algo, mode, tau, controller, quorum in arms:
    # Thm 4.1: eta_s·tau invariant — AdaptiveTau rescales it on re-plan
    sfl = SFLConfig(n_clients=M, tau=tau, cut_units=1,
                    lr_server=ETA / tau, lr_client=ETA,
                    lr_global=1.0, population=POP,
                    quorum=quorum, staleness_discount=0.5)
    res = engine.run_rounds(algo, cfg, sfl, params0,
                            lambda r: make_client_batches(ds, parts, r, 2,
                                                          seed=0),
                            sched, key, rounds=ROUNDS, chunk_size=4,
                            mode=mode, controller=controller)
    # tau_per_round is Optional on hand-built results; run_rounds fills it
    taus = (res.tau_per_round if res.tau_per_round is not None
            else np.full(ROUNDS, sfl.tau, np.int64))
    steps = int(taus.sum())
    print(f"{name:18s} rounds {ROUNDS:3d}  server-steps {steps:4d}  "
          f"sim time {res.sim_time:6.1f}s  "
          f"steps/sim-s {steps / res.sim_time:5.2f}  "
          f"final loss {res.round_loss[-1]:.4f}")
    if controller is not None:
        print(f"{'':18s} tau trajectory: {[int(t) for t in taus]}")
print("\nEq.12: per-round time = max(t_straggler, tau*t_server) — the tau "
      "server steps ride inside the straggler wait for free, and the "
      "controller re-sizes tau as the straggler gap moves. The semi-async "
      "arm drops the barrier entirely: versions commit at the quorum "
      "arrival and the slow tier's late work folds in staleness-discounted "
      "(core/events.py).")
