"""Straggler resilience (paper Fig. 2 + Eq. 12 scenario): equal simulated
wall-clock budget, vanilla SplitFed vs MU-SplitFed with τ planned from
observed delays (τ* = t_straggler/t_server, capped). The unbalanced server
updates overlap the straggler wait, so MU-SplitFed packs τ server steps
into each (equally long) round — more optimization progress per second.
Learning rates follow Thm 4.1's coupling (η_s = η_c/τ).

    PYTHONPATH=src python examples/straggler_resilience.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import straggler as strag
from repro.core.splitfed import mu_splitfed_round
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params

M, T_SERVER, BUDGET = 4, 0.5, 120.0
cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
key = jax.random.PRNGKey(0)
params0 = untie_params(cfg, init_params(cfg, key))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
parts = dirichlet_partition(np.arange(256) % 8, M, alpha=0.5)

rng = np.random.default_rng(0)
delays_all = strag.DelayModel(base=1.0, scale=3.0).sample(rng, M, 200)
t_straggler = float(delays_all.max(1).mean())
tau_star = strag.plan_tau(t_straggler, T_SERVER, tau_max=8)
print(f"observed straggler time {t_straggler:.2f}s, t_server {T_SERVER}s "
      f"-> planned tau* = {tau_star} (capped at 8)")
print(f"equal simulated budget: {BUDGET:.0f}s\n")

for name, tau in (("vanilla(tau=1)", 1), (f"mu-splitfed(tau={tau_star})",
                                          tau_star)):
    # Thm 4.1: eta_s = eta_c / tau — server lr shrinks with tau
    sfl = SFLConfig(n_clients=M, tau=tau, cut_units=1,
                    lr_server=8e-3 / tau, lr_client=8e-3,
                    lr_global=1.0)
    fn = jax.jit(lambda p, b, m, k: mu_splitfed_round(cfg, sfl, p, b, m, k))
    params, t, r = params0, 0.0, 0
    mask = jnp.ones((M,), jnp.float32)
    loss = float("nan")
    while True:
        dt = strag.round_time_mu_splitfed(delays_all[r % 200], np.ones(M),
                                          T_SERVER, tau)
        if t + dt > BUDGET:
            break
        host = make_client_batches(ds, parts, r, 2, seed=0)
        b = {k2: jnp.asarray(v) for k2, v in host.items()}
        params, metrics = fn(params, b, mask, jax.random.fold_in(key, r))
        loss = float(metrics.loss.mean())
        t += dt
        r += 1
    print(f"{name:22s} rounds {r:3d}  server-steps {r*tau:4d}  "
          f"final loss {loss:.4f}  time used {t:6.1f}s")
print("\nEq.12: per-round time = max(t_straggler, tau*t_server) — the tau "
      "server steps ride inside the straggler wait for free; the same "
      "budget buys tau x more server optimization.")
