"""Straggler resilience (paper Fig. 2 + Eq. 12 scenario): equal simulated
wall-clock budget, vanilla SplitFed vs MU-SplitFed with τ planned from
observed delays (τ* = t_straggler/t_server, capped). The unbalanced server
updates overlap the straggler wait, so MU-SplitFed packs τ server steps
into each (equally long) round — more optimization progress per second.
Learning rates follow Thm 4.1's coupling (η_s = η_c/τ).

The whole run goes through the unified engine: the delay trace is one
precomputed schedule, the budget decides the round count host-side, and
the rounds themselves execute as fused on-device scans.

    PYTHONPATH=src python examples/straggler_resilience.py
"""
import jax
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import engine
from repro.core import straggler as strag
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params

M, T_SERVER, BUDGET = 4, 0.5, 120.0
cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
key = jax.random.PRNGKey(0)
params0 = untie_params(cfg, init_params(cfg, key))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
parts = dirichlet_partition(np.arange(256) % 8, M, alpha=0.5)

sched = strag.make_schedule(0, 200, M, straggler_scale=3.0,
                            t_server=T_SERVER)
t_straggler = float(sched.delays.max(1).mean())
tau_star = strag.plan_tau(t_straggler, T_SERVER, tau_max=8)
print(f"observed straggler time {t_straggler:.2f}s, t_server {T_SERVER}s "
      f"-> planned tau* = {tau_star} (capped at 8)")
print(f"equal simulated budget: {BUDGET:.0f}s\n")

for name, tau in (("vanilla(tau=1)", 1), (f"mu-splitfed(tau={tau_star})",
                                          tau_star)):
    # Thm 4.1: eta_s = eta_c / tau — server lr shrinks with tau
    sfl = SFLConfig(n_clients=M, tau=tau, cut_units=1,
                    lr_server=8e-3 / tau, lr_client=8e-3,
                    lr_global=1.0)
    # budget -> round count, host-side from the precomputed schedule
    per_round = np.array([strag.round_time_mu_splitfed(
        *sched.row(r), T_SERVER, tau) for r in range(sched.n_rounds)])
    rounds = int(np.searchsorted(np.cumsum(per_round), BUDGET))
    res = engine.run_rounds("mu_splitfed", cfg, sfl, params0,
                            lambda r: make_client_batches(ds, parts, r, 2,
                                                          seed=0),
                            sched, key, rounds=rounds, chunk_size=8)
    print(f"{name:22s} rounds {rounds:3d}  server-steps {rounds*tau:4d}  "
          f"final loss {res.round_loss[-1]:.4f}  "
          f"time used {res.sim_time:6.1f}s")
print("\nEq.12: per-round time = max(t_straggler, tau*t_server) — the tau "
      "server steps ride inside the straggler wait for free; the same "
      "budget buys tau x more server optimization.")
