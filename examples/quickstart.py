"""Quickstart: MU-SplitFed in ~40 lines on a tiny LM, through the unified
engine — the rounds run as ONE fused on-device scan per chunk, not a
Python loop.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core import engine, make_schedule
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params

# 1. a small model + the paper's algorithm config: M clients, τ unbalanced
#    server steps per round, cut after the first unit. The client fleet is
#    a ClientPopulation — one homogeneous cohort here; swap in tiered
#    cohorts / Markov availability for heterogeneity (see
#    examples/straggler_resilience.py)
from repro.core.population import ClientPopulation

cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
sfl = SFLConfig(n_clients=4, tau=2, cut_units=1,
                lr_server=5e-3, lr_client=1e-3, lr_global=1.0,
                population=ClientPopulation.single(4))

# 2. params + non-IID federated data
key = jax.random.PRNGKey(0)
params = untie_params(cfg, init_params(cfg, key))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
parts = dirichlet_partition(np.arange(256) % 8, sfl.n_clients, alpha=0.5)

# 3. train: the engine precomputes the straggler/participation schedule as
#    (R, M) data and scans Algorithm 1 over rounds on-device — the server
#    does τ ZO updates per client round on the stale embedding, clients
#    update from a single returned scalar
sched = make_schedule(seed=0, n_rounds=10,
                      population=ClientPopulation.resolve(sfl))
result = engine.run_rounds(
    "mu_splitfed", cfg, sfl, params,
    lambda r: make_client_batches(ds, parts, r, batch_per_client=2, seed=0),
    sched, key, rounds=10, chunk_size=5)
for r, loss in enumerate(result.round_loss):
    print(f"round {r}: mean client loss {loss:.4f}")
