"""Quickstart: MU-SplitFed in ~40 lines on a tiny LM.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SFLConfig, get_config
from repro.core.splitfed import mu_splitfed_round
from repro.data import SyntheticLM, dirichlet_partition, make_client_batches
from repro.models import init_params, untie_params

# 1. a small model + the paper's algorithm config: M clients, τ unbalanced
#    server steps per round, cut after the first unit
cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
sfl = SFLConfig(n_clients=4, tau=2, cut_units=1,
                lr_server=5e-3, lr_client=1e-3, lr_global=1.0)

# 2. params + non-IID federated data
key = jax.random.PRNGKey(0)
params = untie_params(cfg, init_params(cfg, key))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
parts = dirichlet_partition(np.arange(256) % 8, sfl.n_clients, alpha=0.5)

# 3. train: one jit'd global round per step — the server does τ ZO updates
#    per client round on the stale embedding, clients update from a single
#    returned scalar (Algorithm 1)
round_fn = jax.jit(lambda p, b, m, k: mu_splitfed_round(cfg, sfl, p, b, m, k))
mask = jnp.ones((sfl.n_clients,), jnp.float32)
for r in range(10):
    host = make_client_batches(ds, parts, r, batch_per_client=2, seed=0)
    batch = {k2: jnp.asarray(v) for k2, v in host.items()}
    params, metrics = round_fn(params, batch, mask, jax.random.fold_in(key, r))
    print(f"round {r}: mean client loss {float(metrics.loss.mean()):.4f}")
