"""End-to-end system behaviour: the training driver round-trips through
checkpoint restart, and the serve driver generates coherent shapes."""
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.slow
def test_train_driver_checkpoint_restart():
    with tempfile.TemporaryDirectory() as d:
        base = [sys.executable, "-m", "repro.launch.train", "--arch",
                "olmo-1b", "--smoke", "--clients", "2", "--batch", "1",
                "--seq", "16", "--ckpt-dir", d, "--ckpt-every", "2"]
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        r1 = subprocess.run(base + ["--rounds", "3"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "round    2" in r1.stdout, r1.stdout + r1.stderr[-2000:]
        r2 = subprocess.run(base + ["--rounds", "5"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "[resume] from round" in r2.stdout, r2.stdout + r2.stderr[-2000:]
        assert "round    4" in r2.stdout
