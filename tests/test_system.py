"""End-to-end system behaviour: the training driver round-trips through
checkpoint restart, and the serve driver generates coherent shapes."""
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.slow
def test_train_driver_checkpoint_restart():
    with tempfile.TemporaryDirectory() as d:
        base = [sys.executable, "-m", "repro.launch.train", "--arch",
                "olmo-1b", "--smoke", "--clients", "2", "--batch", "1",
                "--seq", "16", "--ckpt-dir", d, "--ckpt-every", "2"]
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        r1 = subprocess.run(base + ["--rounds", "3"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "round    2" in r1.stdout, r1.stdout + r1.stderr[-2000:]
        r2 = subprocess.run(base + ["--rounds", "5"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "[resume] from round" in r2.stdout, r2.stdout + r2.stderr[-2000:]
        assert "round    4" in r2.stdout


def test_train_driver_validates_async_policy_flags():
    """Parse-time validation (no silent clamping inside the DES): quorum
    must fit the RESOLVED fleet, the discount must be a weight base in
    [0, 1], geometry overrides must be non-negative, and the sparse
    timeline only exists under --async."""
    from repro.launch import train
    base = ["--arch", "olmo-1b", "--smoke", "--rounds", "1", "--clients",
            "4", "--batch", "1", "--seq", "16"]
    with pytest.raises(SystemExit):        # quorum > n_clients
        train.main(base + ["--async", "--quorum", "9"])
    with pytest.raises(SystemExit):        # quorum > resolved population M
        train.main(base + ["--async", "--quorum", "5",
                           "--population", "tiered:2x1.0,2x0.5"])
    with pytest.raises(SystemExit):        # discount outside [0, 1]
        train.main(base + ["--async", "--quorum", "2",
                           "--staleness-discount", "1.5"])
    with pytest.raises(SystemExit):        # negative geometry override
        train.main(base + ["--async", "--quorum", "2", "--k-max", "-1"])
    with pytest.raises(SystemExit):        # sparse without --async
        train.main(base + ["--timeline", "sparse"])
