"""End-to-end system behaviour: the training driver round-trips through
checkpoint restart (including SIGKILL mid-run), and the serve driver
generates coherent shapes."""
import json
import os
import signal
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.slow
def test_train_driver_checkpoint_restart():
    with tempfile.TemporaryDirectory() as d:
        base = [sys.executable, "-m", "repro.launch.train", "--arch",
                "olmo-1b", "--smoke", "--clients", "2", "--batch", "1",
                "--seq", "16", "--ckpt-dir", d, "--ckpt-every", "2"]
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        r1 = subprocess.run(base + ["--rounds", "3"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "round    2" in r1.stdout, r1.stdout + r1.stderr[-2000:]
        r2 = subprocess.run(base + ["--rounds", "5"], capture_output=True,
                            text=True, timeout=560, cwd="/root/repo", env=env)
        assert "[resume] from round" in r2.stdout, r2.stdout + r2.stderr[-2000:]
        assert "round    4" in r2.stdout


@pytest.mark.slow
def test_train_driver_sigkill_and_resume_bit_identical():
    """The host-kill fault (--faults kill=R) SIGKILLs the driver — no
    cleanup, no atexit, the real crash mode — right after the chunk
    containing round R flushes and BEFORE that chunk's checkpoint lands.
    A rerun without the kill flag must resume from the last good chunk
    boundary and produce a per-round loss log bit-identical to an
    uninterrupted run."""
    with tempfile.TemporaryDirectory() as d:
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}

        def cmd(tag, *extra):
            return [sys.executable, "-m", "repro.launch.train", "--arch",
                    "olmo-1b", "--smoke", "--clients", "2", "--batch", "1",
                    "--seq", "16", "--rounds", "8", "--chunk-size", "2",
                    "--ckpt-every", "2", "--ckpt-dir", f"{d}/{tag}_ckpt",
                    "--log-jsonl", f"{d}/{tag}.jsonl", *extra]

        ref = subprocess.run(cmd("ref"), capture_output=True, text=True,
                             timeout=560, cwd="/root/repo", env=env)
        assert "round    7" in ref.stdout, ref.stdout + ref.stderr[-2000:]

        killed = subprocess.run(cmd("kill", "--faults", "kill=5"),
                                capture_output=True, text=True, timeout=560,
                                cwd="/root/repo", env=env)
        assert killed.returncode == -signal.SIGKILL, \
            killed.stdout + killed.stderr[-2000:]
        assert "[faults] kill=5: SIGKILL after chunk [4, 6)" in killed.stdout
        # the killed chunk's rounds flushed but its checkpoint never
        # landed: the newest surviving step is the previous boundary
        steps = sorted(s for s in os.listdir(f"{d}/kill_ckpt")
                       if s.startswith("step_"))
        assert steps[-1] == "step_0000000003", steps

        resumed = subprocess.run(cmd("kill"), capture_output=True,
                                 text=True, timeout=560, cwd="/root/repo",
                                 env=env)
        assert "[resume] from round 4" in resumed.stdout, \
            resumed.stdout + resumed.stderr[-2000:]
        assert "round    7" in resumed.stdout

        def losses(path):
            with open(path) as fh:
                rows = [json.loads(line) for line in fh]
            return {r["round"]: r["loss"] for r in rows
                    if r.get("kind") == "round"}

        # RunLog truncated the killed run's replayed rows on resume, so
        # the stitched log must equal the uninterrupted one bit for bit
        ref_losses = losses(f"{d}/ref.jsonl")
        assert len(ref_losses) == 8
        assert losses(f"{d}/kill.jsonl") == ref_losses


def test_train_driver_validates_async_policy_flags():
    """Parse-time validation (no silent clamping inside the DES): quorum
    must fit the RESOLVED fleet, the discount must be a weight base in
    [0, 1], geometry overrides must be non-negative, and the sparse
    timeline only exists under --async."""
    from repro.launch import train
    base = ["--arch", "olmo-1b", "--smoke", "--rounds", "1", "--clients",
            "4", "--batch", "1", "--seq", "16"]
    with pytest.raises(SystemExit):        # quorum > n_clients
        train.main(base + ["--async", "--quorum", "9"])
    with pytest.raises(SystemExit):        # quorum > resolved population M
        train.main(base + ["--async", "--quorum", "5",
                           "--population", "tiered:2x1.0,2x0.5"])
    with pytest.raises(SystemExit):        # discount outside [0, 1]
        train.main(base + ["--async", "--quorum", "2",
                           "--staleness-discount", "1.5"])
    with pytest.raises(SystemExit):        # negative geometry override
        train.main(base + ["--async", "--quorum", "2", "--k-max", "-1"])
    with pytest.raises(SystemExit):        # sparse without --async
        train.main(base + ["--timeline", "sparse"])


def test_train_driver_validates_fleet_flags():
    """Parse-time validation of the fleet-scale knobs: --loader subset and
    --fleet-shard only exist on the sparse async path, shard counts must
    fit the device pool, and ring/k_max geometry must divide the 'data'
    axis — all rejected before any device work."""
    from repro.launch import train
    base = ["--arch", "olmo-1b", "--smoke", "--rounds", "1", "--clients",
            "4", "--batch", "1", "--seq", "16"]
    sparse = base + ["--async", "--quorum", "2", "--timeline", "sparse"]
    with pytest.raises(SystemExit):        # subset loader without sparse
        train.main(base + ["--loader", "subset"])
    with pytest.raises(SystemExit):        # subset under async but dense
        train.main(base + ["--async", "--quorum", "2", "--loader",
                           "subset"])
    with pytest.raises(SystemExit):        # fleet-shard without sparse
        train.main(base + ["--fleet-shard", "1"])
    with pytest.raises(SystemExit):        # negative shard count
        train.main(sparse + ["--fleet-shard", "-1"])
    with pytest.raises(SystemExit):        # more shards than devices
        train.main(sparse + ["--fleet-shard", "4097"])


def test_train_driver_rejects_indivisible_fleet_geometry():
    """An explicit ring/k_max geometry that does not divide the 'data'
    mesh axis is a launch-time SystemExit with the fix in the message,
    not a mid-run GSPMD surprise (subprocess: needs a multi-device
    host)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
         "--smoke", "--rounds", "1", "--clients", "6", "--batch", "1",
         "--seq", "16", "--async", "--quorum", "2", "--timeline",
         "sparse", "--k-max", "6", "--ring-capacity", "6",
         "--fleet-shard", "4"],
        capture_output=True, text=True, timeout=560, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert r.returncode != 0
    assert "does not divide the 'data' axis" in r.stderr, r.stderr[-2000:]


@pytest.mark.slow
def test_train_driver_sharded_run_matches_unsharded():
    """The sharded-placement gate on a forced 4-device host mesh:
    --loader subset reproduces the fleet-gather run bit for bit (host
    staging never touches device math), and --fleet-shard 4 matches the
    replicated run within the sharded reduction-order budget
    (test_distributed allows 2e-5 per round; 4 training rounds here)."""
    script = (
        "import numpy as np, jax\n"
        "from repro.launch import train\n"
        "a = ['--arch','olmo-1b','--smoke','--rounds','4','--tau','1',\n"
        "     '--clients','8','--batch','1','--seq','16','--async',\n"
        "     '--quorum','3','--staleness-discount','0.5','--timeline',\n"
        "     'sparse','--k-max','8','--ring-capacity','16',\n"
        "     '--chunk-size','2','--straggler-scale','0.4']\n"
        "ref = train.main(a)\n"
        "sub = train.main(a + ['--loader','subset'])\n"
        "shd = train.main(a + ['--loader','subset','--fleet-shard','4'])\n"
        "def d(x, y):\n"
        "    return max(float(jax.numpy.max(jax.numpy.abs(u - v)))\n"
        "               for u, v in zip(jax.tree.leaves(x),\n"
        "                               jax.tree.leaves(y)))\n"
        "ds, dh = d(ref, sub), d(ref, shd)\n"
        "assert ds == 0.0, f'subset != fleet gather: {ds}'\n"
        "assert dh <= 5e-4, f'sharded diverges from unsharded: {dh}'\n"
        "print('SHARDED_OK', ds, dh)\n")
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr[-2000:]
