"""Observability layer (repro.obs): span tracer nesting/export and its
zero-cost-when-disabled contract, TelemetrySink ring/window semantics,
metrics registry, run-log resume truncation, the measure() helper — and
the engine integration gates: the sim telemetry producer is bit-identical
to ChunkInfo-derived values (sync AND async), the measured producer
brackets every chunk, telemetry survives controller re-plans and
checkpoint resume."""
import json
import tempfile
import threading
import tracemalloc

import jax
import numpy as np
import pytest

from conftest import tiny_lm_cfg
from repro.ckpt import Checkpointer, latest_step
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.models import init_params, untie_params
from repro.obs import (Measurement, RoundTelemetry, RunLog, SpanTracer,
                       TelemetrySink, get_registry, install, measure,
                       read_jsonl, span)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import _NULL_SPAN, get_tracer

M = 4
ROUNDS = 8


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_export_roundtrip(tmp_path):
    """Nested spans record depth and containment; both export formats
    round-trip every record."""
    tr = SpanTracer()
    prev = install(tr)
    try:
        with span("outer", k=1):
            with span("inner"):
                pass
            with span("inner2") as s:
                s.set(rounds=8)
    finally:
        install(prev)
    recs = {r.name: r for r in tr.records()}
    assert set(recs) == {"outer", "inner", "inner2"}
    assert recs["outer"].depth == 0
    assert recs["inner"].depth == recs["inner2"].depth == 1
    # children complete inside the parent window
    for child in ("inner", "inner2"):
        assert recs[child].start >= recs["outer"].start
        assert (recs[child].start + recs[child].duration
                <= recs["outer"].start + recs["outer"].duration + 1e-9)
    assert recs["outer"].attrs == {"k": 1}
    assert recs["inner2"].attrs == {"rounds": 8}

    jl = tmp_path / "t.jsonl"
    assert tr.export_jsonl(str(jl)) == 3
    rows = [json.loads(l) for l in jl.read_text().splitlines()]
    assert {r["name"] for r in rows} == {"outer", "inner", "inner2"}

    ct = tmp_path / "t.json"
    assert tr.export_chrome(str(ct)) == 3
    events = json.loads(ct.read_text())["traceEvents"]
    assert all(e["ph"] == "X" for e in events)
    assert {e["name"] for e in events} == {"outer", "inner", "inner2"}


def test_no_tracer_means_null_span():
    """With no installed tracer the probe returns ONE shared null object —
    no allocation, no clock read, nothing recorded."""
    prev = install(None)
    try:
        s1, s2 = span("a", x=1), span("b")
        assert s1 is s2 is _NULL_SPAN
        with s1 as s:
            s.set(anything=0)        # no-op, must not raise
    finally:
        install(prev)


def test_disabled_tracer_is_null_and_records_nothing():
    tr = SpanTracer(enabled=False)
    prev = install(tr)
    try:
        assert span("hot") is _NULL_SPAN
        with span("hot"):
            pass
    finally:
        install(prev)
    assert tr.records() == []


def test_install_returns_previous():
    tr = SpanTracer()
    prev = install(tr)
    try:
        assert get_tracer() is tr
    finally:
        assert install(prev) is tr


def test_tracer_thread_safety():
    tr = SpanTracer()
    prev = install(tr)

    def work(i):
        for _ in range(50):
            with span("w", tid=i):
                pass
    try:
        ts = [threading.Thread(target=work, args=(i,)) for i in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
    finally:
        install(prev)
    assert len(tr.records()) == 200
    # depth is per-thread: no cross-thread nesting bleed
    assert {r.depth for r in tr.records()} == {0}


# ---------------------------------------------------------------------------
# telemetry sink
# ---------------------------------------------------------------------------

def _rec(start, stop, source="sim", **kw):
    return RoundTelemetry(start, stop, source, "scan",
                          np.arange(stop - start, dtype=np.float64), **kw)


def test_sink_ring_window_latest():
    sink = TelemetrySink(capacity=3)
    for i in range(5):
        sink.emit(_rec(i * 2, i * 2 + 2))
    assert sink.emitted == 5
    assert len(sink.records()) == 3            # ring dropped the oldest 2
    assert sink.records()[0].start == 4
    # window query: overlap semantics, half-open
    w = sink.window(5, 7)
    assert [(r.start, r.stop) for r in w] == [(4, 6), (6, 8)]
    assert sink.window(100, 200) == ()
    assert sink.latest().start == 8
    assert sink.latest("measured") is None
    sink.clear()
    assert sink.records() == [] and sink.emitted == 5


def test_sink_rejects_bad_capacity():
    with pytest.raises(ValueError):
        TelemetrySink(capacity=0)


def test_sink_summary_and_t_wall_stamp():
    sink = TelemetrySink()
    sink.emit(_rec(0, 4))
    sink.emit(_rec(0, 4, source="measured", dispatch_seconds=0.5,
                   staging_seconds=0.1, staging_bytes=1024))
    s = sink.summary()
    assert s["emitted"] == 2 and set(s["sources"]) == {"sim", "measured"}
    assert s["sources"]["measured"]["staging_bytes"] == 1024
    assert s["sources"]["sim"]["rounds"] == 4
    assert all(r.t_wall > 0 for r in sink.records())   # stamped on emit


def test_round_telemetry_json():
    r = _rec(2, 5, quorum_wait=np.array([1.0, 2.0, 3.0]))
    j = r.to_json()
    assert j["start"] == 2 and j["stop"] == 5
    assert j["durations"] == [0.0, 1.0, 2.0]
    assert j["quorum_wait"] == [1.0, 2.0, 3.0]
    assert j["cohort_arrival"] is None
    json.dumps(j)                               # fully serializable
    assert r.n_rounds == 3


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    for v in (0.001, 0.01, 0.01, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["c"]["value"] == 5
    assert snap["g"]["value"] == 2.5
    assert snap["h"]["count"] == 4
    assert snap["h"]["min"] == 0.001 and snap["h"]["max"] == 5.0
    # quantile estimate is a bucket upper bound >= the true value
    assert h.quantile(0.5) >= 0.01
    with pytest.raises(TypeError):
        reg.gauge("c")                          # kind collision
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    assert get_registry() is get_registry()     # process-wide singleton


# ---------------------------------------------------------------------------
# run log
# ---------------------------------------------------------------------------

def test_runlog_write_resume_and_log_every(tmp_path):
    p = str(tmp_path / "run.jsonl")
    with RunLog(p, log_every=2) as log:
        for r in range(6):
            log.round(r, loss=float(r))
        log.chunk(0, 4, telemetry=(_rec(0, 4),), extra=1)
        log.chunk(4, 8, telemetry=())
    rounds = read_jsonl(p, kind="round")
    assert [r["round"] for r in rounds] == [0, 2, 4]   # log_every=2
    chunks = read_jsonl(p, kind="chunk")
    assert len(chunks) == 2
    assert chunks[0]["telemetry"][0]["durations"] == [0.0, 1.0, 2.0, 3.0]

    # resume at round 4: round rows >= 4 and chunks reaching past 4 drop
    with RunLog(p, resume_round=4) as log:
        log.round(4, loss=9.0)
    rows = read_jsonl(p)
    kinds = [(r["kind"], r.get("round", r.get("start"))) for r in rows]
    assert kinds == [("round", 0), ("round", 2), ("chunk", 0), ("round", 4)]


def test_read_jsonl_tolerates_partial_tail(tmp_path):
    p = tmp_path / "r.jsonl"
    p.write_text('{"kind": "round", "round": 0}\n{"kind": "rou')
    assert len(read_jsonl(str(p))) == 1


# ---------------------------------------------------------------------------
# measure helper
# ---------------------------------------------------------------------------

def test_measure_returns_triple():
    m = measure(lambda n: bytes(n), 1 << 20)
    assert isinstance(m, Measurement)
    assert len(m.result) == 1 << 20
    assert m.seconds > 0
    assert m.peak_bytes >= 1 << 20


def test_measure_exception_safe():
    """A raising body must still stop tracemalloc (bench_timeline's
    refuse-dense path raises SystemExit inside measure)."""
    with pytest.raises(SystemExit):
        measure(lambda: (_ for _ in ()).throw(SystemExit(2)))
    assert not tracemalloc.is_tracing()


# ---------------------------------------------------------------------------
# engine integration: the two producers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0)
    sched = strag.make_schedule(0, ROUNDS, M, straggler_scale=2.0,
                                participation=0.5, t_server=0.1, t_comm=0.2)

    def batch_fn(r):
        k = jax.random.fold_in(jax.random.PRNGKey(99), r)
        t = jax.random.randint(k, (M, 2, 16), 0, cfg.vocab_size)
        return {"tokens": t, "labels": t}

    return cfg, params, sfl, sched, batch_fn, key


def _async_sfl(timeline="sparse"):
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=3, delay=DelayModel(base=0.3, scale=0.0)),
        Cohort(name="slow", n=1, delay=DelayModel(base=4.0, scale=0.0)),
    ))
    return SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                     lr_client=1e-3, lr_global=1.0, population=pop,
                     quorum=2, staleness_discount=0.5, timeline=timeline)


def _run_with_sink(cfg, sfl, params, batch_fn, sched, key, *, mode,
                   algorithm="mu_splitfed", rounds=ROUNDS, chunk=3, **kw):
    sink = TelemetrySink()
    infos = []
    res = engine.run_rounds(algorithm, cfg, sfl, params, batch_fn, sched,
                            key, rounds=rounds, mode=mode, chunk_size=chunk,
                            telemetry=sink,
                            chunk_callback=lambda i, p, s: infos.append(i),
                            **kw)
    return res, sink, infos


@pytest.mark.parametrize("mode", ["scan", "python"])
def test_sim_telemetry_bit_identical_to_chunkinfo_sync(setup, mode):
    """The acceptance gate: the sim producer's per-round durations are the
    SAME array values as ChunkInfo.round_times, flush by flush (per chunk
    in scan mode; python mode flushes — and therefore emits — per round)."""
    cfg, params, sfl, sched, batch_fn, key = setup
    _, sink, infos = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                    mode=mode)
    sims = sink.records("sim")
    expected = ([(0, 3), (3, 6), (6, 8)] if mode == "scan"
                else [(r, r + 1) for r in range(ROUNDS)])
    assert [(r.start, r.stop) for r in sims] == \
        [(i.start, i.stop) for i in infos] == expected
    for r, i in zip(sims, infos):
        assert np.array_equal(r.durations, i.round_times)   # bit-for-bit
        assert r.quorum_wait is None                        # sync path
        assert r.mode == mode
    # single-cohort schedule: one arrival latency per chunk, positive
    for r in sims:
        assert r.cohort_arrival is not None
        assert r.cohort_arrival.shape == (1,)
        assert float(r.cohort_arrival[0]) > 0


@pytest.mark.parametrize("timeline", ["dense", "sparse"])
def test_sim_telemetry_bit_identical_to_chunkinfo_async(setup, timeline):
    """Same gate on the async path (dense timeline and the sparse DES
    stream): durations == commit-interval round_times, and quorum_wait is
    populated from the timeline."""
    cfg, params, _, sched, batch_fn, key = setup
    sfl = _async_sfl(timeline)
    _, sink, infos = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                    mode="async",
                                    algorithm="async_mu_splitfed")
    sims = sink.records("sim")
    assert [(r.start, r.stop) for r in sims] == \
        [(i.start, i.stop) for i in infos]
    for r, i in zip(sims, infos):
        assert np.array_equal(r.durations, i.round_times)
        assert r.quorum_wait is not None
        assert r.quorum_wait.shape == r.durations.shape
        assert np.all(r.quorum_wait >= 0)


def test_measured_telemetry_brackets_every_chunk(setup):
    """The measured producer emits one record per chunk covering the same
    [start, stop) windows, with positive dispatch time and staged bytes."""
    cfg, params, sfl, sched, batch_fn, key = setup
    _, sink, infos = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                    mode="scan")
    meas = sink.records("measured")
    assert [(r.start, r.stop) for r in meas] == \
        [(i.start, i.stop) for i in infos]
    for r in meas:
        assert r.dispatch_seconds > 0
        assert r.staging_bytes > 0
        assert r.durations.shape == (r.n_rounds,)
        assert np.allclose(r.durations.sum(), r.dispatch_seconds)
        assert r.t_wall > 0


def test_telemetry_survives_controller_replans(setup):
    """AdaptiveTau re-plans at chunk boundaries; the sink keeps records
    from every segment and the controller's window sees telemetry."""
    cfg, params, _, sched, batch_fn, key = setup
    sfl = _async_sfl("sparse")
    seen = []

    class Probe(engine.AdaptiveTau):
        def update(self, round_idx, window, metrics):
            if window is not None:
                seen.append(window.telemetry)
            return super().update(round_idx, window, metrics)

    ctl = Probe(tau_max=8, source="measured")
    res, sink, _ = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                  mode="async",
                                  algorithm="async_mu_splitfed",
                                  controller=ctl)
    assert ctl.trace, "controller never re-planned"
    assert res.tau_per_round is not None
    # every controller step after the first chunk saw telemetry records,
    # including measured ones (its configured source)
    assert seen and all(len(w) > 0 for w in seen)
    assert all(any(r.source == "measured" for r in w) for w in seen)
    # sink retained records across re-plans: full round coverage per source
    for src in ("sim", "measured"):
        covered = sorted((r.start, r.stop) for r in sink.records(src))
        assert covered[0][0] == 0
        assert all(a[1] == b[0] for a, b in zip(covered, covered[1:]))


def test_adaptive_tau_measured_vs_sim_sources(setup):
    """source='measured' consumes wall-clock durations (machine-dependent)
    yet still produces a valid monotone plan; source='sim' is unchanged by
    the sink being attached."""
    cfg, params, sfl, sched, batch_fn, key = setup
    base = engine.AdaptiveTau(tau_max=8)
    r_nosink = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn,
                                 sched, key, rounds=ROUNDS, mode="scan",
                                 chunk_size=3, controller=base)
    sim_ctl = engine.AdaptiveTau(tau_max=8, source="sim")
    r_sim, _, _ = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                 mode="scan", controller=sim_ctl)
    assert np.array_equal(r_nosink.tau_per_round, r_sim.tau_per_round)
    meas_ctl = engine.AdaptiveTau(tau_max=8, source="measured")
    r_meas, _, _ = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                  mode="scan", controller=meas_ctl)
    assert r_meas.tau_per_round is not None
    assert np.all(r_meas.tau_per_round >= 1)


def test_adaptive_tau_rejects_unknown_source():
    with pytest.raises(ValueError):
        engine.AdaptiveTau(source="psychic")


def test_telemetry_across_checkpoint_resume(setup):
    """Kill after 4 rounds, resume from the checkpoint with a fresh sink:
    the resumed run's sim records start at the resume round, and together
    the two sinks tile [0, ROUNDS) with the SAME durations as an
    uninterrupted run."""
    cfg, params, sfl, sched, batch_fn, key = setup
    R, C = 6, 2
    _, full_sink, _ = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                     mode="scan", rounds=R, chunk=C)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        _, sink1, _ = _run_with_sink(cfg, sfl, params, batch_fn, sched, key,
                                     mode="scan", rounds=4, chunk=C,
                                     checkpointer=ck, ckpt_every=C)
        ck.wait()
        restored, meta = ck.restore(params, latest_step(d))
        _, sink2, _ = _run_with_sink(cfg, sfl, restored, batch_fn, sched,
                                     key, mode="scan", rounds=R, chunk=C,
                                     start_round=meta["step"] + 1)
    recs = sink1.records("sim") + sink2.records("sim")
    assert [(r.start, r.stop) for r in recs] == [(0, 2), (2, 4), (4, 6)]
    stitched = np.concatenate([r.durations for r in recs])
    reference = np.concatenate([r.durations
                                for r in full_sink.records("sim")])
    assert np.array_equal(stitched, reference)


def test_engine_spans_cover_hot_path(setup):
    """With a tracer installed, one run emits the stage/dispatch/flush
    span triple per chunk (and compile spans), properly nested."""
    cfg, params, sfl, sched, batch_fn, key = setup
    tr = SpanTracer()
    prev = install(tr)
    try:
        engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched,
                          key, rounds=ROUNDS, mode="scan", chunk_size=3)
    finally:
        install(prev)
    names = [r.name for r in tr.records()]
    for want in ("engine.stage", "engine.dispatch", "engine.flush"):
        assert names.count(want) == 3, (want, names)


def test_telemetry_off_emits_nothing(setup):
    """No sink, no tracer: the engine takes the untimed path — nothing is
    recorded anywhere."""
    cfg, params, sfl, sched, batch_fn, key = setup
    tr = SpanTracer(enabled=False)
    prev = install(tr)
    try:
        res = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn,
                                sched, key, rounds=ROUNDS, mode="scan",
                                chunk_size=3)
    finally:
        install(prev)
    assert tr.records() == []
    assert res.round_loss.shape == (ROUNDS,)
