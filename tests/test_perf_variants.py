"""The §Perf optimization variants must preserve exact algorithm semantics:
banded SWA == masked full attention; counter-noise SPSA is a valid gaussian
with exact seed replay; grouped MoE dispatch routes tokens correctly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, maxdiff, tiny_lm_cfg
from repro.configs import SFLConfig, get_config
from repro.core import zo
from repro.core.splitfed import mu_splitfed_round
from repro.models import attention as A
from repro.models import init_params, untie_params
from repro.models.layers import apply_rope


def test_banded_swa_equals_masked_full():
    cfg = get_config("mixtral-8x22b", smoke=True).replace(
        dtype="float32", sliding_window=8)
    key = jax.random.PRNGKey(0)
    p = A.init_attn(cfg, key)
    B, S = 2, 64                       # S = 8w -> banded path triggers
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    banded = A.gqa_attention(cfg, p, x, pos)
    # naive masked-full reference
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = apply_rope(((x @ p["wq"]).reshape(B, S, H, dh)).swapaxes(1, 2),
                   pos[:, None, :], cfg.rope_theta)
    k = apply_rope(((x @ p["wk"]).reshape(B, S, Hkv, dh)).swapaxes(1, 2),
                   pos[:, None, :], cfg.rope_theta)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh).swapaxes(1, 2)
    qg = q.reshape(B, Hkv, H // Hkv, S, dh)
    sc = jnp.einsum("bkgsd,bktd->bkgst", qg, k) / np.sqrt(dh)
    sc = sc + A._mask(S, S, True, 8)
    out = jnp.einsum("bkgst,bktd->bskgd", jax.nn.softmax(sc, -1), v)
    ref = out.reshape(B, S, H * dh) @ p["wo"]
    assert float(jnp.max(jnp.abs(banded - ref))) < 1e-5


def test_counter_noise_is_valid_gaussian_and_replayable():
    params = {"a": jnp.zeros((5000,)), "b": jnp.zeros((37, 11)),
              "c": jnp.zeros((3, 4, 5, 6))}
    key = jax.random.PRNGKey(3)
    u1 = zo.tree_noise(key, params, dist="counter")
    u2 = zo.tree_noise(key, params, dist="counter")
    assert maxdiff(u1, u2) == 0.0                       # deterministic
    flat = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(u1)])
    assert abs(float(flat.mean())) < 0.05
    assert abs(float(flat.std()) - 1.0) < 0.05
    # distinct streams per leaf
    assert float(jnp.max(jnp.abs(u1["a"][:37 * 11]
                                 - u1["b"].reshape(-1)))) > 0.1
    # exact replay through the SPSA step
    loss = lambda p: sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))
    new_p, _, (keys, coeffs) = zo.spsa_step(loss, params, key, 1e-3, 0.1, 2,
                                            dist="counter")
    rep = zo.replay_updates(params, keys, coeffs, dist="counter")
    assert maxdiff(new_p, rep) == 0.0


def test_round_with_counter_noise_trains():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    sfl = SFLConfig(n_clients=2, tau=2, cut_units=1,
                    perturbation_dist="counter")
    batches = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16, M=2)
    mask = jnp.ones((2,), jnp.float32)
    p2, m = mu_splitfed_round(cfg, sfl, params, batches, mask, key)
    assert bool(jnp.isfinite(m.loss).all())
    assert maxdiff(p2, params) > 0
    # counter and threefry rounds agree in structure, differ in draw
    sfl_g = SFLConfig(n_clients=2, tau=2, cut_units=1)
    p3, _ = mu_splitfed_round(cfg, sfl_g, params, batches, mask, key)
    assert jax.tree.structure(p2) == jax.tree.structure(p3)


def test_grouped_moe_dispatch_routes_correctly():
    """With ample capacity, grouped dispatch must equal a dense softmax-topk
    mixture computed directly."""
    import dataclasses
    from repro.models import moe as M
    cfg = get_config("mixtral-8x22b", smoke=True).replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(2)
    p = M.init_moe(cfg, key)
    x = jax.random.normal(key, (3, 16, cfg.d_model), jnp.float32)
    out, aux = M.apply_moe(cfg, p, x)
    # dense reference
    E, k, _, d_e = M.moe_dims(cfg)
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for e in range(E):
        h = jax.nn.silu(xf @ p["wi"][e]) * (xf @ p["wg"][e])
        y = h @ p["wo"][e]
        w = ((idx == e) * gates).sum(-1)[:, None]
        ref = ref + w * y
    err = float(jnp.max(jnp.abs(out.reshape(-1, cfg.d_model) - ref)))
    assert err < 1e-4, err
    assert bool(jnp.isfinite(aux))
