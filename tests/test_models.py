"""Model correctness: cut-point invariance, split/merge round-trips,
prefill/decode vs full-forward logits consistency."""
import jax
import jax.numpy as jnp
import pytest

from conftest import maxdiff
from repro.configs import get_config
from repro.models import (client_forward, decode_step, init_params, logits_fn,
                          loss_fn, merge_params, prefill, server_forward,
                          split_params, forward_from_cut, untie_params)

ARCHS_FAST = ["olmo-1b", "qwen3-14b", "xlstm-350m", "jamba-1.5-large-398b"]


def _f32(arch):
    return get_config(arch, smoke=True).replace(dtype="float32")


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.n_image_tokens:
        b["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS_FAST)
def test_cut_point_invariance(arch):
    """The loss must be identical for every cut position (the split is a
    pure re-partitioning of the same computation)."""
    cfg = _f32(arch)
    key = jax.random.PRNGKey(1)
    params = untie_params(cfg, init_params(cfg, key))
    batch = _batch(cfg, key)
    n_cuts = cfg.n_encoder_layers if cfg.is_encoder_decoder else cfg.n_units
    losses = [float(forward_from_cut(cfg, params, batch, c))
              for c in range(1, n_cuts + 1)]
    for l in losses[1:]:
        assert abs(l - losses[0]) < 1e-4, losses


@pytest.mark.parametrize("arch", ARCHS_FAST + ["whisper-tiny"])
def test_split_merge_roundtrip(arch):
    cfg = _f32(arch)
    key = jax.random.PRNGKey(2)
    params = untie_params(cfg, init_params(cfg, key))
    cp, sp = split_params(cfg, params, cfg.default_cut_units)
    merged = merge_params(cfg, cp, sp)
    assert jax.tree.structure(merged) == jax.tree.structure(params)
    assert maxdiff(merged, params) == 0.0


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x22b", "qwen3-14b",
                                  "mistral-nemo-12b", "xlstm-350m",
                                  "jamba-1.5-large-398b",
                                  "llama-3.2-vision-90b"])
def test_prefill_decode_matches_full_forward(arch):
    """prefill(S) then decode(S) must reproduce the full-forward logits at
    positions S-1 and S (exact in f32 up to accumulation order)."""
    cfg = _f32(arch)
    if cfg.moe is not None:   # capacity dropping is not causal; lift capacity
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = _batch(cfg, key, B, S)
    batch["tokens"] = toks[:, :S]
    lg_pre, cache = prefill(cfg, params, batch, cache_len=S + 4)
    lg_dec, _ = decode_step(cfg, params, toks[:, S:S + 1], cache, S)
    full = dict(batch)
    full["tokens"] = toks
    lg_full = logits_fn(cfg, params, full)
    assert float(jnp.max(jnp.abs(lg_pre[:, 0] - lg_full[:, S - 1]))) < 1e-3
    assert float(jnp.max(jnp.abs(lg_dec[:, 0] - lg_full[:, S]))) < 1e-3


def test_sliding_window_ring_buffer():
    """Decode past the window: ring cache must equal full-context SWA."""
    cfg = _f32("mixtral-8x22b")
    import dataclasses
    cfg = cfg.replace(sliding_window=8,
                      moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)
    lg, cache = prefill(cfg, params, {"tokens": toks[:, :S]},
                        cache_len=S + 4)
    for i in range(4):
        lg, cache = decode_step(cfg, params, toks[:, S + i:S + i + 1], cache,
                                S + i)
    full = logits_fn(cfg, params, {"tokens": toks})
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, S + 3]))) < 1e-3


def test_client_server_forward_compose():
    cfg = _f32("olmo-1b")
    key = jax.random.PRNGKey(5)
    params = untie_params(cfg, init_params(cfg, key))
    batch = _batch(cfg, key)
    cp, sp = split_params(cfg, params, 2)
    h = client_forward(cfg, cp, batch)
    assert h["h"].shape == (2, 16, cfg.d_model)
    loss = server_forward(cfg, sp, h, batch)
    assert abs(float(loss) - float(loss_fn(cfg, params, batch))) < 1e-4
