"""Fault-injection subsystem (core/faults.py + the DES fault branches in
core/events.py): plan grammar and validation, the zero-fault bit-exactness
contract, per-dispatch conservation accounting, dense == sparse fault
agreement, quorum-timeout liveness, the degenerate-fleet stall diagnosis,
and the AdaptiveQuorum degradation controller."""
import dataclasses

import numpy as np
import pytest

from repro.configs import SFLConfig
from repro.core import engine, events
from repro.core import straggler as strag
from repro.core.faults import (STALE_CORRUPT, STALE_CRASH, STALE_LOST,
                               FaultPlan, parse_faults, record_checksum)
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.obs.telemetry import RoundTelemetry

M = 6
V = 10
FAULT_COLS = ("started", "crashed", "lost", "corrupt", "dups", "retries",
              "timeouts")


def _sched(seed=0, rounds=12, m=M, **kw):
    kw.setdefault("straggler_scale", 1.0)
    kw.setdefault("participation", 0.8)
    kw.setdefault("t_server", 0.1)
    kw.setdefault("t_comm", 0.1)
    return strag.make_schedule(seed, rounds, m, **kw)


def _fields_equal(a, b):
    """Timeline fields that differ between two compiles."""
    out = []
    for f in dataclasses.fields(events.Timeline):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if (x is None) != (y is None) or \
                (x is not None and not np.array_equal(x, y)):
            out.append(f.name)
    return out


# ---------------------------------------------------------------------------
# plan grammar + validation
# ---------------------------------------------------------------------------

def test_parse_faults_grammar_roundtrip():
    p = parse_faults("faults:crash=0.2,loss=0.1,dup=0.05,corrupt=0.01,"
                     "backoff=0.25,kill=6")
    assert p == FaultPlan(crash=0.2, loss=0.1, dup=0.05, corrupt=0.01,
                          backoff=0.25, kill_round=6)
    # prefix optional, cohort overrides, describe round-trips the spec
    q = parse_faults("crash=0.05,crash@slow=0.4")
    assert q.overrides == (("crash", "slow", 0.4),)
    assert q.describe() == "crash=0.05,crash@slow=0.4"
    assert parse_faults("").describe() == "none"


@pytest.mark.parametrize("spec", [
    "crash",                    # missing value
    "jitter=0.5",               # unknown key
    "backoff@slow=1.0",         # only rate fields take @cohort
])
def test_parse_faults_rejects_bad_specs(spec):
    with pytest.raises(ValueError):
        parse_faults(spec)


def test_fault_plan_validates_rates():
    with pytest.raises(ValueError):
        FaultPlan(crash=1.5)
    with pytest.raises(ValueError):
        FaultPlan(backoff=-1.0)
    with pytest.raises(ValueError):
        FaultPlan(overrides=(("jitter", "slow", 0.1),))
    # kill alone is a driver-side schedule, not an event perturbation
    assert not FaultPlan(kill_round=6).any()
    assert FaultPlan(crash=0.1).any()
    assert FaultPlan(overrides=(("loss", "slow", 0.2),)).any()


def test_resolve_overrides_need_matching_population():
    plan = FaultPlan(overrides=(("crash", "slow", 1.0),))
    with pytest.raises(ValueError, match="need a population"):
        plan.resolve(M)
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=4, delay=DelayModel(base=0.3, scale=0.0)),))
    with pytest.raises(ValueError, match="unknown cohort"):
        plan.resolve(pop.n_clients, pop)


# ---------------------------------------------------------------------------
# the zero-fault contract: FaultPlan.none() is byte-identical to faults=None
# ---------------------------------------------------------------------------

def test_zero_fault_plan_is_bit_exact_dense_and_sparse():
    sched = _sched()
    kw = dict(quorum=3, discount=0.5, tau=2)
    clean = events.compile_timeline(sched, V, **kw)
    inert = events.compile_timeline(sched, V, faults=FaultPlan.none(),
                                    quorum_timeout=0.0, **kw)
    assert _fields_equal(clean, inert) == []
    sparse = events.compile_sparse_timeline(
        sched, V, faults=FaultPlan.none(), **kw).densify()
    assert _fields_equal(clean, sparse) == []
    # and the fault accounting reports an unperturbed run
    for col in FAULT_COLS[1:]:           # started counts real dispatches
        assert np.all(getattr(inert, col) == 0), col


# ---------------------------------------------------------------------------
# conservation + dense == sparse under active plans
# ---------------------------------------------------------------------------

PLANS = [
    FaultPlan(crash=0.3),
    FaultPlan(loss=0.4),
    FaultPlan(corrupt=0.3, dup=0.3),
    FaultPlan(crash=0.2, loss=0.2, dup=0.2, corrupt=0.2, backoff=0.25),
]


@pytest.mark.parametrize("plan", PLANS, ids=lambda p: p.describe())
def test_fault_conservation_and_sparse_agreement(plan):
    """Every dispatch is accounted exactly once: delivered (staleness >=
    -1), or dropped with a reason code matching the per-version counters.
    The sparse DES reproduces the dense compiler field-for-field, fault
    columns included."""
    sched = _sched()
    kw = dict(quorum=3, discount=0.5, tau=2, faults=plan,
              quorum_timeout=1.0)
    tl = events.compile_timeline(sched, V, **kw)
    for v in range(V):
        rows = tl.round_of_origin == v
        st = tl.staleness[rows]
        assert tl.started[v] == rows.sum()
        assert (st == STALE_CRASH).sum() == tl.crashed[v]
        assert (st == STALE_LOST).sum() == tl.lost[v]
        assert (st == STALE_CORRUPT).sum() == tl.corrupt[v]
        delivered = (st >= -1).sum()
        assert delivered == tl.started[v] - tl.crashed[v] - tl.lost[v] \
            - tl.corrupt[v]
        assert delivered == tl.start_mask[v].sum()
    # dropped rows never commit and carry no weight
    dropped = tl.staleness < -1
    assert np.all(tl.commit_idx[dropped] == -1)
    # weights stay normalized per commit despite the drops
    sums = tl.apply_w.sum(axis=1)
    applied = tl.applied > 0
    assert np.allclose(sums[applied], 1.0, atol=1e-6)
    assert np.all(sums[~applied] == 0.0)

    got = events.compile_sparse_timeline(sched, V, **kw).densify()
    assert _fields_equal(tl, got) == []


def test_duplicates_are_counted_not_applied():
    """dup faults are deduped structurally (one in-flight record per
    client): dup=1.0 must change the `dups` counter and NOTHING else."""
    sched = _sched()
    kw = dict(quorum=3, discount=0.5, tau=2, quorum_timeout=1.0)
    base = FaultPlan(crash=0.3, loss=0.2, corrupt=0.15)
    a = events.compile_timeline(
        sched, V, faults=dataclasses.replace(base, dup=1.0), **kw)
    b = events.compile_timeline(
        sched, V, faults=dataclasses.replace(base, dup=0.0), **kw)
    assert _fields_equal(a, b) == ["dups"]
    assert a.dups.sum() > 0 and b.dups.sum() == 0


def test_loss_retries_and_retransmission_latency():
    """Lost attempts consume retries; a delivery that needed resends
    arrives strictly later than its loss-free counterpart (one uplink
    t_comm per attempt). Only version 0 is comparable across the two
    runs — both dispatch its wave at t=0 with identical delays; later
    broadcasts drift apart once losses reshape the commit schedule."""
    sched = _sched()
    kw = dict(quorum=3, discount=0.5, tau=2, quorum_timeout=2.0)
    lossy = events.compile_timeline(sched, V, faults=FaultPlan(loss=0.5),
                                    max_retries=3, **kw)
    clean = events.compile_timeline(sched, V,
                                    **dict(kw, quorum_timeout=0.0))
    assert lossy.retries.sum() > 0
    assert lossy.lost.sum() > 0          # some exhaust all 4 attempts
    clean_at = {int(c): t for v, c, t in
                zip(clean.round_of_origin, clean.client_id,
                    clean.arrival_time) if v == 0}
    grew = 0
    for v, c, t, st in zip(lossy.round_of_origin, lossy.client_id,
                           lossy.arrival_time, lossy.staleness):
        if v != 0 or st < -1:
            continue
        assert t >= clean_at[int(c)] - 1e-12
        grew += t > clean_at[int(c)] + 1e-12
    assert grew > 0


def test_cohort_override_targets_only_named_cohort():
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=4, delay=DelayModel(base=0.3, scale=0.0)),
        Cohort(name="slow", n=2, delay=DelayModel(base=2.0, scale=0.0)),
    ))
    sched = strag.make_schedule(0, 12, population=pop, t_server=0.1,
                                t_comm=0.05)
    tl = events.compile_timeline(
        sched, V, quorum=2, discount=0.5, tau=2, quorum_timeout=1.0,
        faults=FaultPlan(overrides=(("crash", "slow", 1.0),)))
    crash_rows = tl.staleness == STALE_CRASH
    assert crash_rows.any()
    assert np.all(tl.client_id[crash_rows] >= 4)      # slow slice only
    # every slow dispatch crashed: no slow client ever delivers
    assert np.all(tl.client_id[tl.staleness >= -1] < 4)


# ---------------------------------------------------------------------------
# liveness: quorum timeouts commit with what arrived; stalls are diagnosed
# ---------------------------------------------------------------------------

def test_quorum_timeout_commits_and_counts():
    sched = _sched()
    tl = events.compile_timeline(sched, V, quorum=5, discount=0.5, tau=2,
                                 faults=FaultPlan(crash=0.5),
                                 quorum_timeout=0.5)
    assert tl.commit_times.shape == (V,)
    assert np.all(np.isfinite(tl.commit_times))
    assert np.all(np.diff(tl.commit_times) >= 0)
    assert tl.timeouts.sum() > 0


def test_degenerate_fleet_stall_is_diagnosed_not_a_deadlock():
    """The regression the quorum_timeout knob exists for: a fleet whose
    every dispatch crashes can never fill any quorum. Without a timeout
    that must be a QuorumStallError naming the fix — not an infinite
    event loop, not a silent under-filled commit."""
    sched = _sched(m=3, rounds=8)
    kw = dict(quorum=2, discount=0.5, tau=2, faults=FaultPlan(crash=1.0))
    with pytest.raises(events.QuorumStallError, match="quorum_timeout"):
        events.compile_timeline(sched, 6, **kw)
    with pytest.raises(events.QuorumStallError, match="quorum_timeout"):
        events.compile_sparse_timeline(sched, 6, **kw)
    # the prescribed fix unsticks both backends
    tl = events.compile_timeline(sched, 6, quorum_timeout=0.5, **kw)
    assert np.all(np.isfinite(tl.commit_times))
    assert tl.started.sum() == tl.crashed.sum()       # nobody ever lands
    got = events.compile_sparse_timeline(sched, 6, quorum_timeout=0.5,
                                         **kw).densify()
    assert _fields_equal(tl, got) == []


def test_zero_fault_run_never_stalls_without_timeout():
    """quorum > arrivals on a clean run is the pre-existing wait-for-all
    semantics (quorum clamps to pending) — the stall guard must not fire
    when no fault plan is active."""
    sched = _sched(m=3, rounds=8)
    tl = events.compile_timeline(sched, 6, quorum=3, discount=0.5, tau=2)
    assert np.all(np.isfinite(tl.commit_times))


# ---------------------------------------------------------------------------
# the AdaptiveQuorum degradation controller
# ---------------------------------------------------------------------------

def _window(started, dropped):
    rec = RoundTelemetry(0, 4, "sim", "async", np.full(4, 0.1),
                         started=started, crashed=dropped)
    return engine.SchedWindow(0, 4, np.zeros((4, M)), np.ones((4, M)),
                              0.1, 0.0, telemetry=(rec,))


def test_adaptive_quorum_tracks_delivery_rate():
    ctl = engine.AdaptiveQuorum(ema=1.0)        # no smoothing: exact rate
    ctl.bind(SFLConfig(n_clients=M, tau=2, cut_units=1, quorum=4))
    assert ctl.update(4, _window(20, 10), {}) == {"quorum": 2}
    assert ctl.update(8, _window(20, 0), {}) == {"quorum": 4}   # recovers
    assert ctl.update(12, _window(20, 20), {}) == {"quorum": 1}  # k_min
    assert ctl.trace == [(4, 2), (8, 4), (12, 1)]
    # round-trips through its state_dict (checkpoint resume)
    fresh = engine.AdaptiveQuorum(ema=1.0)
    fresh.load_state_dict(ctl.state_dict())
    assert fresh.k0 == 4 and fresh.rate == ctl.rate


def test_adaptive_quorum_ignores_windows_without_accounting():
    ctl = engine.AdaptiveQuorum()
    ctl.bind(SFLConfig(n_clients=M, tau=2, cut_units=1, quorum=4))
    assert ctl.update(4, None, {}) == {}
    assert ctl.update(4, _window(0, 0), {}) == {}     # no sink attached


def test_adaptive_quorum_validates_binding():
    with pytest.raises(ValueError, match="k_min"):
        engine.AdaptiveQuorum(k_min=0)
    ctl = engine.AdaptiveQuorum()
    with pytest.raises(ValueError, match="quorum > 0"):
        ctl.bind(SFLConfig(n_clients=M, tau=2, cut_units=1,
                                  quorum=0))


# ---------------------------------------------------------------------------
# the wire-format integrity primitive
# ---------------------------------------------------------------------------

def test_record_checksum_detects_bit_flips():
    keys = np.arange(8, dtype=np.uint32)
    coeffs = np.linspace(0.0, 1.0, 8, dtype=np.float32)
    crc = record_checksum(keys, coeffs)
    assert crc == record_checksum(keys.copy(), coeffs.copy())
    flipped = coeffs.copy()
    flipped[3] = np.nextafter(flipped[3], 2.0, dtype=np.float32)
    assert crc != record_checksum(keys, flipped)
