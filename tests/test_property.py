"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import straggler as strag
from repro.core import theory, zo
from repro.data.partition import dirichlet_partition
from repro.kernels import ref
from repro.kernels.ops import zo_update_leaf

SET = dict(max_examples=20, deadline=None)


@settings(**SET)
@given(n=st.integers(8, 400), seed=st.integers(0, 2**31 - 1),
       coeff=st.floats(-2.0, 2.0, allow_nan=False))
def test_zo_update_kernel_equals_oracle(n, seed, coeff):
    x = jnp.arange(n, dtype=jnp.float32) * 0.01
    got = zo_update_leaf(x, seed, coeff)
    want = ref.zo_update_ref(x, seed, coeff)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5


@settings(**SET)
@given(n_samples=st.integers(20, 300), n_clients=st.integers(2, 10),
       alpha=st.floats(0.05, 10.0), seed=st.integers(0, 1000))
def test_dirichlet_partition_invariants(n_samples, n_clients, alpha, seed):
    labels = np.arange(n_samples) % 7
    parts = dirichlet_partition(labels, n_clients, alpha, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n_samples                    # covering
    assert len(np.unique(allidx)) == n_samples         # disjoint
    assert all(len(p) >= 1 for p in parts)             # non-empty


@settings(**SET)
@given(t_straggler=st.floats(0.5, 100.0), t_server=st.floats(0.01, 5.0),
       T0=st.integers(10, 10000))
def test_eq12_straggler_independence(t_straggler, t_server, T0):
    """Paper Eq. 12: with τ = t_straggler/t_server, total time becomes
    T0·t_server — independent of the straggler delay."""
    tau = max(t_straggler / t_server, 1.0)
    T1 = T0 / tau
    total = T1 * t_straggler
    assert abs(total - min(T0 * t_server,
                           T0 * t_straggler)) / total < 1e-6


@settings(**SET)
@given(d=st.integers(1000, 10**9), tau=st.integers(1, 64),
       M=st.integers(1, 64))
def test_rate_improves_with_tau_and_M(d, tau, M):
    r_base = theory.mu_splitfed_rate(1.0, 1.0, 1000, 1, 1, d, 1.0, 1.0, 1.0)
    r_tau = theory.mu_splitfed_rate(1.0, 1.0, 1000, tau, M, d, 1.0, 1.0, 1.0)
    assert r_tau <= r_base + 1e-9


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 4.0))
def test_delay_model_nonnegative_and_deadline(seed, scale):
    rng = np.random.default_rng(seed)
    dm = strag.DelayModel(base=1.0, scale=scale)
    delays = dm.sample(rng, 8, 3)
    assert (delays >= 1.0).all()
    mask = strag.deadline_mask(delays[0], deadline=1.5)
    assert mask.sum() >= 1                              # never drop everyone
    assert ((delays[0] <= 1.5) | (mask == 0) | (mask == 1)).all()


@settings(**SET)
@given(seed=st.integers(0, 1000), shape=st.sampled_from(
    [(3, 5), (17,), (2, 2, 9)]))
def test_perturb_replay_closure(seed, shape):
    """perturb(+λ) then apply_update(2λ·...) composition: x - c·u must be
    recoverable from the record alone."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, shape)}
    rec_key = jax.random.fold_in(key, 1)
    up = zo.apply_update(params, rec_key, 0.25)
    manual = jax.tree.map(
        lambda p, u: p - 0.25 * u, params, zo.tree_noise(rec_key, params))
    assert float(jnp.max(jnp.abs(up["w"] - manual["w"]))) == 0.0
