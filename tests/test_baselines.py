"""Baseline algorithms: GAS staleness semantics, FedAvg / FedLoRA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, maxdiff, tiny_lm_cfg
from repro.configs import SFLConfig
from repro.core.baselines import (fedavg_round, fedlora_round, gas_init_state,
                                  gas_round)
from repro.models import init_params, loss_fn, untie_params
from repro.optim.lora import init_lora

M = 3


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    batches = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16, M=M)
    sfl = SFLConfig(n_clients=M, tau=1, cut_units=1)
    return cfg, params, batches, sfl


def test_gas_stale_clients_use_buffer(setup):
    """A stale client's server replica must train from the buffered
    activation: swapping that client's FRESH data must not change the
    result when the client is marked stale."""
    cfg, params, batches, sfl = setup
    state = gas_init_state(cfg, sfl, params, batches)
    fresh = jnp.array([1.0, 0.0, 1.0])
    rk = jax.random.PRNGKey(2)
    p1, s1, m1 = gas_round(cfg, sfl, params, state, batches, fresh, rk)
    # perturb client 1's fresh batch only
    b2 = jax.tree.map(lambda a: a.copy(), batches)
    b2 = {k: v.at[1].set(jnp.roll(v[1], 3, axis=-1)) for k, v in b2.items()}
    p2, s2, m2 = gas_round(cfg, sfl, params, state, b2, fresh, rk)
    # server-side aggregation identical (stale h used for client 1)...
    from repro.models import split_params
    _, xs1 = split_params(cfg, p1, 1)
    _, xs2 = split_params(cfg, p2, 1)
    assert maxdiff(xs1, xs2) < 1e-6
    # ...and the buffer keeps the OLD activation for the stale client
    assert maxdiff(jax.tree.map(lambda a: a[1], s1.h_buffer),
                   jax.tree.map(lambda a: a[1], state.h_buffer)) == 0.0


def test_gas_fresh_clients_update_buffer(setup):
    cfg, params, batches, sfl = setup
    state = gas_init_state(cfg, sfl, params, batches)
    fresh = jnp.ones((M,), jnp.float32)
    b2 = jax.tree.map(lambda a: jnp.roll(a, 1, axis=-1), batches)
    _, s2, _ = gas_round(cfg, sfl, params, state, b2, fresh,
                         jax.random.PRNGKey(3))
    assert maxdiff(s2.h_buffer, state.h_buffer) > 0


def test_fedavg_descends(setup):
    cfg, params, batches, _ = setup
    mask = jnp.ones((M,), jnp.float32)
    p = params
    for r in range(5):
        p = fedavg_round(cfg, p, batches, mask, lr=5e-3)
    l0 = np.mean([float(loss_fn(cfg, params,
                                jax.tree.map(lambda a: a[m], batches)))
                  for m in range(M)])
    l1 = np.mean([float(loss_fn(cfg, p,
                                jax.tree.map(lambda a: a[m], batches)))
                  for m in range(M)])
    assert l1 < l0


def test_fedlora_trains_only_adapters(setup):
    cfg, params, batches, _ = setup
    lora = init_lora(cfg, params, rank=2, key=jax.random.PRNGKey(4))
    mask = jnp.ones((M,), jnp.float32)
    lora2 = fedlora_round(cfg, params, lora, batches, mask, lr=1e-2)
    assert maxdiff(lora2, lora) > 0          # adapters moved
    # base params untouched by construction (they're never returned)


def test_fedavg_respects_mask(setup):
    cfg, params, batches, _ = setup
    mask = jnp.zeros((M,), jnp.float32).at[0].set(1.0)
    p1 = fedavg_round(cfg, params, batches, mask, lr=1e-3)
    scr = jax.tree.map(lambda a: a.at[1:].set(0), batches)
    p2 = fedavg_round(cfg, params, scr, mask, lr=1e-3)
    assert maxdiff(p1, p2) < 1e-7
