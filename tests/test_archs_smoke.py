"""Assignment requirement: for each assigned architecture, instantiate a
REDUCED config and run one forward/train step on CPU asserting output shapes
and no NaNs. (Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.registry import ASSIGNED
from repro.core import zo
from repro.models import init_params, loss_fn, logits_fn, untie_params

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + ["paper-opt-1.3b"])
def test_smoke_forward_and_zo_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    batch = _batch(cfg, key)

    # forward: finite loss
    loss = loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # logits shape (decoder-only archs)
    if not cfg.is_encoder_decoder:
        logits = logits_fn(cfg, params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one ZO train step: params change, still finite
    new_params, delta, _ = zo.spsa_step(
        lambda p: loss_fn(cfg, p, batch), params, key, eps=1e-3, lr=1e-4)
    assert bool(jnp.isfinite(delta))
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed, f"{arch}: ZO step did not move parameters"
    loss2 = loss_fn(cfg, new_params, batch)
    assert bool(jnp.isfinite(loss2))
