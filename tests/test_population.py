"""ClientPopulation API: single-cohort bit-compatibility with the legacy
scalar schedules, per-seed Markov determinism, cohort composition, the CLI
grammar, and the AdaptiveTau controller's convergence to the static
plan_tau answer."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import tiny_lm_cfg
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag
from repro.core.population import (ClientPopulation, Cohort, DelayModel,
                                   parse_population)
from repro.models import init_params, untie_params


# ---------------------------------------------------------------------------
# single-cohort shorthand == legacy scalar path, bit for bit
# ---------------------------------------------------------------------------

def test_single_cohort_reproduces_legacy_schedule():
    """The deprecated scalar knobs and an explicit single-iid-cohort
    population must consume the RNG identically: every schedule array is
    bit-for-bit equal."""
    legacy = strag.make_schedule(7, 12, 5, straggler_scale=1.5,
                                 participation=0.6, deadline=3.0)
    pop = ClientPopulation.single(5, straggler_scale=1.5, participation=0.6)
    via_pop = strag.make_schedule(7, 12, population=pop, deadline=3.0)
    for f in ("delays", "participation", "deadline", "masks", "fresh_median"):
        assert np.array_equal(getattr(legacy, f), getattr(via_pop, f)), f


def test_resolve_path_from_sfl_scalars():
    """ClientPopulation.resolve(sfl) on a scalar-knob config is the same
    single cohort the shorthand builds."""
    sfl = SFLConfig(n_clients=6, straggler_rate=2.0, participation=0.5)
    pop = ClientPopulation.resolve(sfl)
    assert pop == ClientPopulation.single(6, straggler_scale=2.0,
                                          participation=0.5)
    # explicit population wins over the scalars
    tiered = parse_population("tiered:3x1.0,3x0.5")
    sfl2 = dataclasses.replace(sfl, population=tiered)
    assert ClientPopulation.resolve(sfl2) is tiered


def test_resolve_rejects_client_count_mismatch():
    pop = parse_population("tiered:2x1.0,2x0.5")
    with pytest.raises(ValueError, match="population has 4"):
        ClientPopulation.resolve(SFLConfig(n_clients=8, population=pop))


def test_population_is_hashable_config():
    """Populations sit inside SFLConfig, which jit treats as a static arg —
    they must hash and compare like any frozen config."""
    a = parse_population("tiered:2x1.0,2x0.5")
    b = parse_population("tiered:2x1.0,2x0.5")
    assert a == b and hash(a) == hash(b)
    assert hash(SFLConfig(n_clients=4, population=a)) == hash(
        SFLConfig(n_clients=4, population=b))


# ---------------------------------------------------------------------------
# cohort composition + markov availability
# ---------------------------------------------------------------------------

def test_cohort_composition_vectors():
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=2, delay=DelayModel(base=0.5, scale=0.0)),
        Cohort(name="slow", n=3, delay=DelayModel(base=4.0, scale=0.0),
               t_comm_scale=4.0),
    ))
    assert pop.n_clients == 5
    assert pop.cohort_ids().tolist() == [0, 0, 1, 1, 1]
    assert pop.t_comm_scales().tolist() == [1.0, 1.0, 4.0, 4.0, 4.0]
    sched = strag.make_schedule(0, 3, population=pop, t_comm=0.1)
    # deterministic per-cohort delays land in the right client slots
    assert np.array_equal(sched.delays,
                          np.tile([0.5, 0.5, 4.0, 4.0, 4.0], (3, 1)))
    # comm time is bounded by the slowest ACTIVE uplink
    assert sched.comm_for(np.array([1, 1, 0, 0, 0])) == pytest.approx(0.1)
    assert sched.comm_for(np.array([1, 1, 1, 0, 0])) == pytest.approx(0.4)


def test_markov_availability_deterministic_per_seed():
    pop = ClientPopulation(cohorts=(
        Cohort(name="m", n=4, delay=DelayModel(base=1.0, scale=0.0),
               availability="markov", p_dropout=0.3, p_recover=0.4),))
    a = strag.make_schedule(11, 30, population=pop)
    b = strag.make_schedule(11, 30, population=pop)
    assert np.array_equal(a.participation, b.participation)
    c = strag.make_schedule(12, 30, population=pop)
    assert not np.array_equal(a.participation, c.participation)
    # the chain actually visits both states
    assert 0.0 < a.participation.mean() < 1.0


def test_markov_chain_alternates_deterministically():
    """p_dropout = p_recover = 1 flips every client every round (the chain
    starts all-up and transitions before round 0 is read)."""
    pop = ClientPopulation(cohorts=(
        Cohort(name="m", n=2, delay=DelayModel(base=1.0, scale=0.0),
               availability="markov", p_dropout=1.0, p_recover=1.0),))
    sched = strag.make_schedule(0, 4, population=pop)
    assert sched.participation.tolist() == [[0, 0], [1, 1], [0, 0], [1, 1]]


def test_markov_never_drops_when_p_dropout_zero():
    """p_dropout = 0 keeps every chain client up forever — the chain draws
    still consume RNG (determinism) but availability is all-ones."""
    pop = ClientPopulation(cohorts=(
        Cohort(name="m", n=3, delay=DelayModel(base=1.0, scale=0.0),
               availability="markov", p_dropout=0.0, p_recover=0.5),))
    sched = strag.make_schedule(5, 10, population=pop)
    assert np.array_equal(sched.participation, np.ones((10, 3), np.float32))


def test_markov_shared_whole_tier_moves_together():
    """availability='markov-shared': ONE chain per cohort — every client in
    the tier is up or down together (correlated outages), deterministic
    per seed, and the per-client 'markov' cohorts are unaffected."""
    pop = ClientPopulation(cohorts=(
        Cohort(name="solo", n=2, delay=DelayModel(base=1.0, scale=0.0),
               availability="markov", p_dropout=0.3, p_recover=0.4),
        Cohort(name="tier", n=3, delay=DelayModel(base=2.0, scale=0.0),
               availability="markov-shared", p_dropout=0.3, p_recover=0.4),
    ))
    a = strag.make_schedule(11, 40, population=pop)
    tier = a.participation[:, 2:]
    assert all(len(set(row.tolist())) == 1 for row in tier)   # moves as one
    assert 0.0 < tier.mean() < 1.0                 # chain visits both states
    b = strag.make_schedule(11, 40, population=pop)
    assert np.array_equal(a.participation, b.participation)
    c = strag.make_schedule(12, 40, population=pop)
    assert not np.array_equal(a.participation, c.participation)


def test_markov_shared_alternates_deterministically():
    """p_dropout = p_recover = 1 flips the whole cohort every round (the
    chain starts up and transitions before round 0 is read) — the shared
    analogue of the per-client alternation test above."""
    pop = ClientPopulation(cohorts=(
        Cohort(name="t", n=3, delay=DelayModel(base=1.0, scale=0.0),
               availability="markov-shared", p_dropout=1.0, p_recover=1.0),))
    sched = strag.make_schedule(0, 4, population=pop)
    assert sched.participation.tolist() == [
        [0, 0, 0], [1, 1, 1], [0, 0, 0], [1, 1, 1]]


def test_parse_population_grammar():
    pop = parse_population("tiered:4x1.0,12x0.2@0.5~0.05/0.2%4",
                           straggler_scale=0.7)
    assert [c.n for c in pop.cohorts] == [4, 12]
    fast, slow = pop.cohorts
    assert fast.delay == DelayModel(base=1.0, scale=0.7)
    assert slow.delay.base == pytest.approx(5.0)
    assert slow.participation == 0.5
    assert (slow.availability, slow.p_dropout, slow.p_recover) == \
        ("markov", 0.05, 0.2)
    assert slow.t_comm_scale == 4.0
    shared = parse_population("tiered:2x1.0,3x0.5~~0.1/0.3").cohorts[1]
    assert (shared.availability, shared.p_dropout, shared.p_recover) == \
        ("markov-shared", 0.1, 0.3)
    with pytest.raises(ValueError, match="bad cohort spec"):
        parse_population("tiered:fastx1.0")
    with pytest.raises(ValueError, match="speed"):
        parse_population("tiered:4x0")


# ---------------------------------------------------------------------------
# AdaptiveTau: converges to plan_tau's static answer when stationary
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))

    def batch_fn(r):
        k = jax.random.fold_in(jax.random.PRNGKey(5), r)
        t = jax.random.randint(k, (4, 1, 16), 0, cfg.vocab_size)
        return {"tokens": t, "labels": t}

    return cfg, params, batch_fn, key


def test_adaptive_tau_converges_to_plan_tau(tiny_setup):
    """On a stationary population (deterministic delays) the controller's
    decision must land on plan_tau's static answer after the first observed
    window and stay there."""
    cfg, params, batch_fn, key = tiny_setup
    t_server, base = 0.25, 2.0
    pop = ClientPopulation(cohorts=(
        Cohort(name="all", n=4, delay=DelayModel(base=base, scale=0.0)),))
    sfl = SFLConfig(n_clients=4, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop)
    sched = strag.make_schedule(0, 8, population=pop, t_server=t_server)
    ctl = engine.AdaptiveTau(tau_max=64)
    res = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=8, chunk_size=2,
                            controller=ctl)
    want = strag.plan_tau(base, t_server)          # = 8
    assert [tau for _, tau in ctl.trace] == [want] * 3
    assert res.tau_per_round.tolist() == [1, 1] + [want] * 6
    # Thm 4.1 lr coupling: η_s·τ invariant under the re-plan
    assert ctl._eta_step == pytest.approx(5e-3 * 1)
    # wall-clock rows reflect the applied τ (Eq. 12 round time)
    assert res.round_times[0] == pytest.approx(max(base, 1 * t_server))
    assert res.round_times[-1] == pytest.approx(max(base, want * t_server))


def test_adaptive_tau_resume_replays_overrides(tiny_setup, tmp_path):
    """Checkpoints record the controller's applied overrides + EMA state;
    apply_resume_overrides replays them so a resumed adaptive run
    continues at the adapted τ/η_s instead of restarting from the CLI
    values."""
    from repro.ckpt import Checkpointer
    cfg, params, batch_fn, key = tiny_setup
    t_server, base = 0.25, 2.0
    pop = ClientPopulation(cohorts=(
        Cohort(name="all", n=4, delay=DelayModel(base=base, scale=0.0)),))
    sfl = SFLConfig(n_clients=4, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop)
    sched = strag.make_schedule(0, 8, population=pop, t_server=t_server)
    ck = Checkpointer(str(tmp_path))
    ctl = engine.AdaptiveTau(tau_max=64)
    engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched, key,
                      rounds=4, chunk_size=2, controller=ctl,
                      checkpointer=ck, ckpt_every=2)
    p2, s2, meta = engine.restore_run(ck, "mu_splitfed", cfg, sfl, params,
                                      batch_fn)
    assert s2 is None                      # stateless: params-only ckpt
    ctl2 = engine.AdaptiveTau(tau_max=64)
    sfl2 = engine.apply_resume_overrides(sfl, meta, ctl2)
    want = strag.plan_tau(base, t_server)
    assert sfl2.tau == want
    assert sfl2.lr_server == pytest.approx(5e-3 / want)  # η_s·τ invariant
    assert ctl2.t_hat == pytest.approx(base)             # EMA restored
    res2 = engine.run_rounds("mu_splitfed", cfg, sfl2, p2, batch_fn, sched,
                             key, rounds=8, start_round=meta["step"] + 1,
                             chunk_size=2, controller=ctl2)
    assert res2.tau_per_round.tolist() == [want] * 4     # no reset to τ=1


def test_controller_scan_matches_python(tiny_setup):
    """The controller fires on identical chunk boundaries in both loop
    modes: trajectories, τ traces, and round times must agree."""
    cfg, params, batch_fn, key = tiny_setup
    pop = parse_population("tiered:2x1.0,2x0.25", straggler_scale=1.0)
    sfl = SFLConfig(n_clients=4, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop)
    sched = strag.make_schedule(0, 6, population=pop, t_server=0.5)
    runs = {}
    for mode in ("python", "scan"):
        ctl = engine.AdaptiveTau(tau_max=8)        # fresh controller state
        runs[mode] = engine.run_rounds("mu_splitfed", cfg, sfl, params,
                                       batch_fn, sched, key, rounds=6,
                                       chunk_size=2, mode=mode,
                                       controller=ctl)
    py, sc = runs["python"], runs["scan"]
    assert np.max(np.abs(py.round_loss - sc.round_loss)) <= 1e-5
    assert np.array_equal(py.tau_per_round, sc.tau_per_round)
    assert np.array_equal(py.round_times, sc.round_times)


def test_controller_deadline_override(tiny_setup):
    """A controller-returned deadline re-derives the straggler-drop masks
    from the schedule's delay rows for all remaining rounds."""
    cfg, params, batch_fn, key = tiny_setup
    pop = ClientPopulation(cohorts=(
        Cohort(name="fast", n=2, delay=DelayModel(base=1.0, scale=0.0)),
        Cohort(name="slow", n=2, delay=DelayModel(base=9.0, scale=0.0)),))
    sfl = SFLConfig(n_clients=4, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop)
    sched = strag.make_schedule(0, 6, population=pop, t_server=0.5)

    class DropSlow:
        def update(self, round_idx, window, metrics):
            return {"deadline": 2.0}               # drops the base-9 tier

    infos = []
    engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched, key,
                      rounds=6, chunk_size=2, controller=DropSlow(),
                      chunk_callback=lambda info, p, s: infos.append(info))
    consumed = np.concatenate([i.masks for i in infos])
    assert np.array_equal(consumed, np.tile([1, 1, 0, 0], (6, 1)))


# ---------------------------------------------------------------------------
# chunked schedule streaming + fleet vectors / sharding specs
# ---------------------------------------------------------------------------

def test_make_schedule_stream_matches_monolithic():
    """Chunked generation consumes ONE shared sampler in round order, so
    concatenating stream chunks is bit-identical to the one-shot
    make_schedule at any chunking — what lets the engine feed the DES
    without ever materializing the full (R, M) schedule."""
    pop = parse_population("tiered:4x1.0@0.8,2x0.2~0.4/0.6%3",
                           straggler_scale=1.5)
    whole = strag.make_schedule(3, 20, population=pop, deadline=4.0,
                                t_server=0.2, t_comm=0.1)
    for chunk_rounds in (1, 7, 64):
        chunks = list(strag.make_schedule_stream(
            3, 20, population=pop, deadline=4.0, t_server=0.2, t_comm=0.1,
            chunk_rounds=chunk_rounds))
        for f in ("delays", "participation", "deadline", "masks",
                  "fresh_median"):
            got = np.concatenate([getattr(c, f) for c in chunks])
            assert np.array_equal(getattr(whole, f), got), \
                f"{f} @ chunk_rounds={chunk_rounds}"
        for f in ("t_server", "t_comm", "t_comm_scale"):
            assert np.array_equal(np.asarray(getattr(whole, f)),
                                  np.asarray(getattr(chunks[0], f))), f


def test_client_vectors_expand_cohorts():
    pop = parse_population("tiered:3x1.0@0.8,2x0.2%4", straggler_scale=1.0)
    vecs = pop.client_vectors()
    assert set(vecs) >= {"cohort_id", "t_comm_scale", "delay_base",
                         "delay_scale", "participation"}
    assert all(v.shape == (5,) for v in vecs.values())
    assert vecs["cohort_id"].tolist() == [0, 0, 0, 1, 1]
    assert np.allclose(vecs["t_comm_scale"][3:], 4.0)
    assert np.allclose(vecs["participation"][:3], 0.8)


def test_population_and_store_pspecs_guard_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.configs import SFLConfig as _SFL
    from repro.core import events
    from repro.sharding import specs

    pop = ClientPopulation.single(16, straggler_scale=1.0)
    ps = specs.population_pspecs(pop.client_vectors(),
                                 axis_sizes={"data": 8})
    assert all(p == P("data") for p in ps.values())     # 16 % 8 == 0
    odd = specs.population_pspecs(
        ClientPopulation.single(5).client_vectors(), axis_sizes={"data": 8})
    assert all(p == P(None) for p in odd.values())      # replicate

    store = events.init_store(_SFL(n_clients=16, tau=2, n_perturbations=2))
    sp = specs.event_store_pspecs(store, axis_sizes={"data": 8})
    for name, v in store.items():
        assert sp[name] == P("data", *((None,) * (v.ndim - 1))), name


def test_plan_event_store_places_ring_on_data_axis():
    from repro.configs.base import MeshConfig
    from repro.sharding import planner

    mesh = MeshConfig(shape=(4, 2), axes=("data", "model"))
    plan = planner.plan_event_store(2048, 10_000, mesh, tau=4, n_pert=2)
    assert plan.slot_axis == "data"                     # 2048 % 4 == 0
    assert plan.client_axis == "data"                   # 10000 % 4 == 0
    assert plan.bytes_per_device == planner.store_bytes(2048, 4, 2) // 4
    odd = planner.plan_event_store(2047, 9_999, mesh)
    assert odd.slot_axis is None and odd.client_axis is None
