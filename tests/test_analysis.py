"""Golden tests for repro.analysis: per rule one positive snippet (must
flag) and one negative snippet (must stay silent), plus suppression
(`# lint: ignore[rule-id]`), baseline semantics, and the CLI exit-code
contract the CI `analysis` job relies on."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (analyze_paths, analyze_source, check_clean,
                            default_rules, load_baseline, save_baseline,
                            split_new)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rules_hit(src, path="snippet.py"):
    return sorted({f.rule for f in analyze_source(src, path)})


# ---------------------------------------------------------------------------
# rule goldens: (rule id, positive snippet, negative snippet)
# ---------------------------------------------------------------------------

GOLDENS = [
    (
        "rng-discipline",
        # positive: global numpy RNG state
        "import numpy as np\n"
        "def draw(n):\n"
        "    return np.random.rand(n)\n",
        # negative: the repo's (seed, stream_tag, ...) keying convention
        "import numpy as np\n"
        "def draw(seed, round_idx, client):\n"
        "    rng = np.random.default_rng((seed, round_idx, client))\n"
        "    return rng.random(4)\n",
    ),
    (
        "rng-discipline",
        # positive: unseeded generator
        "import numpy as np\nrng = np.random.default_rng()\n",
        # negative: seeded scalar
        "import numpy as np\n"
        "def f(seed):\n    return np.random.default_rng(seed)\n",
    ),
    (
        "rng-discipline",
        # positive: stdlib random global state
        "import random\n"
        "def pick(xs):\n    return random.choice(xs)\n",
        # negative: stdlib allowed for an explicitly constructed instance
        "import random\n"
        "def pick(xs, seed):\n    return random.Random(seed).choice(xs)\n",
    ),
    (
        "jax-key-reuse",
        # positive: key consumed twice with no split
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.uniform(key, (2,))\n"
        "    return a + b\n",
        # negative: split before the second consumption
        "import jax\n"
        "def f(key):\n"
        "    k1, k2 = jax.random.split(key)\n"
        "    a = jax.random.normal(k1, (2,))\n"
        "    b = jax.random.uniform(k2, (2,))\n"
        "    return a + b\n",
    ),
    (
        "jax-key-reuse",
        # positive: loop consumes a key derived outside it
        "import jax\n"
        "def f(key, n):\n"
        "    out = []\n"
        "    for i in range(n):\n"
        "        out.append(jax.random.normal(key, (2,)))\n"
        "    return out\n",
        # negative: per-iteration fold_in (the engine's fold_in_keys idiom)
        "import jax\n"
        "def f(key, n):\n"
        "    out = []\n"
        "    for i in range(n):\n"
        "        k = jax.random.fold_in(key, i)\n"
        "        out.append(jax.random.normal(k, (2,)))\n"
        "    return out\n",
    ),
    (
        "trace-leak",
        # positive: fresh jax.jit per call (PR 4's trace-count bug)
        "import jax\n"
        "def step(params, batch):\n"
        "    fn = jax.jit(lambda p, b: p)\n"
        "    return fn(params, batch)\n",
        # negative: routed through the _cached_jit registry
        "import jax\n"
        "from repro.core.engine import _cached_jit\n"
        "def step(algo, cfg, sfl, params, batch):\n"
        "    fn = _cached_jit(algo, 'scan', cfg, sfl,\n"
        "                     lambda: jax.jit(lambda p, b: p))\n"
        "    return fn(params, batch)\n",
    ),
    (
        "trace-leak",
        # positive: jit under a non-caching decorator
        "import jax\n"
        "def make(cfg):\n"
        "    return jax.jit(lambda x: x * cfg)\n",
        # negative: module-level registry store (decode_step_jit pattern)
        "import jax\n"
        "_REG = {}\n"
        "def make(cfg):\n"
        "    fn = _REG.get(cfg)\n"
        "    if fn is None:\n"
        "        fn = jax.jit(lambda x: x * cfg)\n"
        "        _REG[cfg] = fn\n"
        "    return fn\n",
    ),
    (
        "host-sync",
        # positive: float() on a jit output every loop iteration
        "def run(chunk_jit, xs):\n"
        "    tot = 0.0\n"
        "    for x in xs:\n"
        "        params, mets = chunk_jit(x, x)\n"
        "        tot += float(mets)\n"
        "    return tot\n",
        # negative: sync once at the chunk boundary, after the loop
        "import numpy as np\n"
        "def run(chunk_jit, xs):\n"
        "    mets = None\n"
        "    for x in xs:\n"
        "        params, mets = chunk_jit(x, x)\n"
        "    return np.asarray(mets)\n",
    ),
    (
        "donation-safety",
        # positive: donated buffer read after the call
        "import jax\n"
        "step = jax.jit(lambda p, b: p, donate_argnums=(0,))\n"
        "def run(params, batch):\n"
        "    out = step(params, batch)\n"
        "    return params\n",
        # negative: donated arg rebound by the call (the engine idiom)
        "import jax\n"
        "step = jax.jit(lambda p, b: p, donate_argnums=(0,))\n"
        "def run(params, batch):\n"
        "    params = step(params, batch)\n"
        "    return params\n",
    ),
    (
        "pallas-budget",
        # positive: BlockSpec last dim off the 128-lane grid
        "from jax.experimental import pallas as pl\n"
        "SPEC = pl.BlockSpec((8, 100), lambda i: (i, 0))\n",
        # negative: aligned block
        "from jax.experimental import pallas as pl\n"
        "SPEC = pl.BlockSpec((8, 128), lambda i: (i, 0))\n",
    ),
    (
        "pallas-budget",
        # positive: record-list constant past the SMEM budget
        "REPLAY_SMEM_RECORDS = 1 << 20\n",
        # negative: the shipped 2048-record budget (16 KiB)
        "REPLAY_SMEM_RECORDS = 2048\n",
    ),
    (
        "pallas-budget",
        # positive: PartitionSpec axis not on any declared mesh
        "from jax.sharding import PartitionSpec as P\n"
        "SPEC = P('batch', None)\n",
        # negative: declared axes only
        "from jax.sharding import PartitionSpec as P\n"
        "SPEC = P(('pod', 'data'), 'model')\n",
    ),
    (
        "pallas-budget",
        # positive: raw kernel call outside the budget-enforcing layer
        "from repro.kernels.zo_update import zo_replay_flat\n"
        "def apply(x, seeds, coeffs):\n"
        "    return zo_replay_flat(x, seeds, coeffs)\n",
        # negative: the ops-layer wrapper that chunks records
        "from repro.kernels.ops import zo_replay_leaf\n"
        "def apply(x, seeds, coeffs):\n"
        "    return zo_replay_leaf(x, seeds, coeffs)\n",
    ),
    (
        "telemetry-purity",
        # positive: host-sync coercion inside a @jax.jit body
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return float(x + 1)\n",
        # negative: coercion at the dispatch boundary, outside jit
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x + 1\n"
        "def run(x):\n"
        "    return float(step(x).sum())\n",
    ),
    (
        "telemetry-purity",
        # positive: obs span probe inside a lax.scan body — fires once at
        # trace time, then never again
        "from jax import lax\n"
        "from repro.obs import span\n"
        "def chunk(xs):\n"
        "    def body(c, x):\n"
        "        with span('round'):\n"
        "            c = c + x\n"
        "        return c, c\n"
        "    return lax.scan(body, 0.0, xs)\n",
        # negative: the engine pattern — span brackets the dispatch, the
        # traced body stays pure
        "from jax import lax\n"
        "from repro.obs import span\n"
        "def chunk(xs):\n"
        "    def body(c, x):\n"
        "        return c + x, c\n"
        "    with span('dispatch'):\n"
        "        return lax.scan(body, 0.0, xs)\n",
    ),
    (
        "telemetry-purity",
        # positive: wall-clock read inside a jit'd lambda
        "import jax, time\n"
        "f = jax.jit(lambda x: x * time.perf_counter())\n",
        # negative: perf_counter bracketing outside the executable
        "import jax, time\n"
        "f = jax.jit(lambda x: x * 2)\n"
        "def timed(x):\n"
        "    t0 = time.perf_counter()\n"
        "    y = jax.block_until_ready(f(x))\n"
        "    return y, time.perf_counter() - t0\n",
    ),
    (
        "telemetry-purity",
        # positive: .item() in a function handed to jax.jit by name
        "import jax\n"
        "def step(x):\n"
        "    return x.sum().item()\n"
        "step_jit = jax.jit(step)\n",
        # negative: same shape, body pure
        "import jax\n"
        "def step(x):\n"
        "    return x.sum()\n"
        "step_jit = jax.jit(step)\n",
    ),
    (
        "fault-isolation",
        # positive: fault-plan rate read inside a @jax.jit body — one
        # plan's outcomes would be frozen into the cached executable
        "import jax\n"
        "@jax.jit\n"
        "def step(x, sfl):\n"
        "    return x * (1.0 - sfl.faults.crash)\n",
        # negative: the engine pattern — faults resolved host-side, the
        # traced function only sees committed batches
        "import jax\n"
        "from repro.core.faults import FaultPlan\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x + 1\n"
        "def run(x, sfl):\n"
        "    rf = sfl.faults.resolve() if sfl.faults else None\n"
        "    return step(x), rf\n",
    ),
    (
        "fault-isolation",
        # positive: fault plan threaded into a lax.scan body by name
        "from jax import lax\n"
        "def chunk(xs, fault_plan):\n"
        "    def body(c, x):\n"
        "        return c + x * fault_plan.crash, c\n"
        "    return lax.scan(body, 0.0, xs)\n",
        # negative: quorum_timeout steers host-side control flow only;
        # the scanned body stays fault-blind
        "from jax import lax\n"
        "def chunk(xs, quorum_timeout):\n"
        "    def body(c, x):\n"
        "        return c + x, c\n"
        "    if quorum_timeout > 0:\n"
        "        xs = xs[:4]\n"
        "    return lax.scan(body, 0.0, xs)\n",
    ),
    (
        "fault-isolation",
        # positive: fault-module constant inside a jit'd lambda (via
        # module alias)
        "import jax\n"
        "from repro.core import faults as cf\n"
        "f = jax.jit(lambda x: x * cf.OUT_CRASH)\n",
        # negative: same constant consumed at the dispatch boundary
        "import jax\n"
        "from repro.core import faults as cf\n"
        "f = jax.jit(lambda x: x * 2)\n"
        "def run(x, fate):\n"
        "    return f(x) if fate != cf.OUT_CRASH else None\n",
    ),
]


@pytest.mark.parametrize(
    "rule,positive,negative", GOLDENS,
    ids=[f"{r}-{i}" for i, (r, _, _) in enumerate(GOLDENS)])
def test_rule_golden(rule, positive, negative):
    assert rule in rules_hit(positive), \
        f"{rule} must flag its positive snippet"
    assert rule not in rules_hit(negative), \
        f"{rule} must not flag its negative snippet"


def test_all_registered_rules_covered():
    """Every registered rule has at least one golden pair above."""
    covered = {r for r, _, _ in GOLDENS}
    assert covered == {r.id for r in default_rules()}


# ---------------------------------------------------------------------------
# suppression + baseline semantics
# ---------------------------------------------------------------------------

def test_inline_ignore_same_line():
    src = ("import numpy as np\n"
           "x = np.random.rand(3)  # lint: ignore[rng-discipline]\n")
    assert analyze_source(src) == []


def test_inline_ignore_line_above_comment_only():
    src = ("import numpy as np\n"
           "# lint: ignore[rng-discipline]\n"
           "x = np.random.rand(3)\n")
    assert analyze_source(src) == []


def test_inline_ignore_wrong_rule_does_not_suppress():
    src = ("import numpy as np\n"
           "x = np.random.rand(3)  # lint: ignore[host-sync]\n")
    assert [f.rule for f in analyze_source(src)] == ["rng-discipline"]


def test_inline_ignore_bare_suppresses_all():
    src = ("import numpy as np\n"
           "x = np.random.rand(3)  # lint: ignore\n")
    assert analyze_source(src) == []


def test_ignore_on_code_line_above_does_not_suppress():
    """The line-above form only counts for comment-only lines."""
    src = ("import numpy as np  # lint: ignore[rng-discipline]\n"
           "x = np.random.rand(3)\n")
    assert [f.rule for f in analyze_source(src)] == ["rng-discipline"]


def test_baseline_split(tmp_path):
    src = ("import numpy as np\n"
           "a = np.random.rand(3)\n"
           "b = np.random.rand(4)\n")
    findings = analyze_source(src, "m.py")
    assert len(findings) == 2
    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), findings[:1])
    new, old = split_new(findings, load_baseline(str(bl)))
    assert len(old) == 1 and len(new) == 1
    assert new[0].line == 3               # the unbaselined second hit


def test_baseline_is_multiset(tmp_path):
    """One baseline entry absorbs exactly one identical finding."""
    src = ("import numpy as np\n"
           "a = np.random.rand(3)\n"
           "a = np.random.rand(3)\n")       # same stripped code text
    f2 = analyze_source(src, "m.py")
    assert f2[0].key() == f2[1].key()
    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), f2[:1])
    new, old = split_new(f2, load_baseline(str(bl)))
    assert len(old) == 1 and len(new) == 1


def test_baseline_missing_file_means_empty():
    assert load_baseline("/nonexistent/baseline.json") == []


# ---------------------------------------------------------------------------
# tree + CLI contract
# ---------------------------------------------------------------------------

def test_shipped_tree_is_clean():
    """The acceptance gate: src/ has no findings beyond the committed
    baseline."""
    new, _ = check_clean([os.path.join(REPO, "src")],
                         os.path.join(REPO, "analysis", "baseline.json"))
    # baseline paths are repo-relative; re-split against relative paths
    findings = analyze_paths(["src"]) if os.getcwd() == REPO else None
    if findings is not None:
        new, _ = split_new(findings,
                           load_baseline("analysis/baseline.json"))
    assert new == [], "\n".join(f.render() for f in new)


def _run_cli(args, cwd):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO, "src")})


@pytest.mark.slow
def test_cli_exit_codes(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("import numpy as np\n"
                     "def f(seed):\n"
                     "    return np.random.default_rng((seed, 1))\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import numpy as np\nx = np.random.rand(3)\n")

    r = _run_cli([str(clean)], cwd=str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    r = _run_cli([str(dirty)], cwd=str(tmp_path))
    assert r.returncode == 1 and "rng-discipline" in r.stdout

    # --update-baseline accepts the finding; the rerun then exits 0
    r = _run_cli([str(dirty), "--update-baseline",
                  "--baseline", str(tmp_path / "bl.json")],
                 cwd=str(tmp_path))
    assert r.returncode == 0
    r = _run_cli([str(dirty), "--baseline", str(tmp_path / "bl.json")],
                 cwd=str(tmp_path))
    assert r.returncode == 0, r.stdout

    # --report writes the findings JSON artifact (the CI upload)
    rep = tmp_path / "report.json"
    r = _run_cli([str(dirty), "--baseline", str(tmp_path / "bl.json"),
                  "--report", str(rep)], cwd=str(tmp_path))
    data = json.loads(rep.read_text())
    assert data["new"] == [] and len(data["baselined"]) == 1


def test_parse_error_is_a_finding(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(:\n")
    fs = analyze_paths([str(bad)])
    assert [f.rule for f in fs] == ["parse-error"]


def test_seeded_violation_per_rule_trips_tree_scan(tmp_path):
    """End-to-end: dropping any single-rule violation into a scanned tree
    makes the analyzer report exactly that rule as new."""
    for rule, positive, _ in GOLDENS:
        mod = tmp_path / "seeded.py"
        mod.write_text(positive)
        findings = analyze_paths([str(tmp_path)])
        assert rule in {f.rule for f in findings}, rule
