"""Unified engine semantics: the chunked on-device scan reproduces the
legacy per-round Python loop for every registered algorithm, the
precomputed schedule matches the historical per-round scalar draws, and
checkpoint resume under the chunked scan is bit-identical."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import maxdiff, tiny_lm_cfg
from repro.ckpt import Checkpointer, latest_step
from repro.configs import SFLConfig
from repro.core import engine
from repro.core import straggler as strag
from repro.models import init_params, untie_params

M = 4
ROUNDS = 8

# every test here runs under the runtime sanitizers: rank-promotion
# errors + transfer_guard('disallow') around each jit'd engine dispatch
# (the dynamic backstop for repro.analysis's host-sync rule)
pytestmark = pytest.mark.usefixtures("jax_sanitizers")


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0)
    # stragglers AND partial participation AND a deadline: the schedule rows
    # must drive every algorithm identically on both loop paths
    sched = strag.make_schedule(0, ROUNDS, M, straggler_scale=2.0,
                                participation=0.5, deadline=4.0,
                                t_server=0.1, t_gen=0.5, t_comm=0.2)

    def batch_fn(r):
        k = jax.random.fold_in(jax.random.PRNGKey(99), r)
        t = jax.random.randint(k, (M, 2, 16), 0, cfg.vocab_size)
        return {"tokens": t, "labels": t}

    return cfg, params, sfl, sched, batch_fn, key


@pytest.mark.parametrize("name", sorted(engine.ALGORITHMS))
def test_scan_matches_python_loop(setup, name):
    """Acceptance gate: chunked scan == legacy per-round loop on the loss
    trajectory (<=1e-5 over >=8 rounds) and on the final params/state, for
    every algorithm, with stragglers + partial participation enabled.
    chunk_size=3 exercises ragged chunking (3+3+2)."""
    cfg, params, sfl, sched, batch_fn, key = setup
    algo = engine.get_algorithm(name)
    py = engine.run_rounds(algo, cfg, sfl, params, batch_fn, sched, key,
                           rounds=ROUNDS, mode="python")
    sc = engine.run_rounds(algo, cfg, sfl, params, batch_fn, sched, key,
                           rounds=ROUNDS, mode="scan", chunk_size=3)
    assert py.round_loss.shape == (ROUNDS,)
    assert np.max(np.abs(py.round_loss - sc.round_loss)) <= 1e-5
    assert maxdiff(py.params, sc.params) <= 1e-5
    if jax.tree.leaves(py.state):               # gas buffer / fedlora adapters
        assert maxdiff(py.state, sc.state) <= 1e-5
    assert np.array_equal(py.round_times, sc.round_times)
    # the stacked metrics honour the adapter's declared spec
    spec = algo.metrics_spec(cfg, sfl)
    for k2, shape in spec.items():
        assert py.metrics[k2].shape == (ROUNDS,) + shape


def test_unknown_algorithm_rejected():
    with pytest.raises(ValueError, match="unknown algorithm"):
        engine.get_algorithm("nope")


def test_get_algorithm_memoizes_and_reuses_jit_cache(setup):
    """get_algorithm returns the SAME adapter per (name, opts), so the
    per-instance executable cache survives across run_rounds calls: a
    repeated identical run must not re-trace the round body (the tracer
    runs the Python body, so a counter in round_fn counts traces)."""
    cfg, params, sfl, sched, batch_fn, key = setup
    traces = []

    @engine.register
    class _Counting(engine.MuSplitFed):
        name = "_trace_counter"

        def round_fn(self, cfg, sfl, p, s, b, m, k):
            traces.append(1)
            return super().round_fn(cfg, sfl, p, s, b, m, k)

    try:
        assert engine.get_algorithm("_trace_counter") is \
            engine.get_algorithm("_trace_counter")
        assert engine.get_algorithm("_trace_counter", eval_loss=True) is \
            engine.get_algorithm("_trace_counter", eval_loss=True)
        kw = dict(rounds=4, mode="scan", chunk_size=2)
        a = engine.run_rounds("_trace_counter", cfg, sfl, params, batch_fn,
                              sched, key, **kw)
        n_first = len(traces)
        assert n_first > 0
        b = engine.run_rounds("_trace_counter", cfg, sfl, params, batch_fn,
                              sched, key, **kw)
        assert len(traces) == n_first          # zero re-traces on rerun
        assert np.array_equal(a.round_loss, b.round_loss)
        # distinct opts resolve to a distinct (fresh) instance
        assert engine.get_algorithm("_trace_counter", eval_loss=False) is not \
            engine.get_algorithm("_trace_counter")
    finally:
        del engine.ALGORITHMS["_trace_counter"]
        for k2 in [k2 for k2 in engine._INSTANCES
                   if k2[0] == "_trace_counter"]:
            del engine._INSTANCES[k2]


def test_make_schedule_deterministic():
    a = strag.make_schedule(7, 12, 5, straggler_scale=1.5, participation=0.6,
                            deadline=3.0)
    b = strag.make_schedule(7, 12, 5, straggler_scale=1.5, participation=0.6,
                            deadline=3.0)
    for f in ("delays", "participation", "deadline", "masks", "fresh_median"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    c = strag.make_schedule(8, 12, 5, straggler_scale=1.5, participation=0.6,
                            deadline=3.0)
    assert not np.array_equal(a.delays, c.delays)


def test_schedule_composes_like_scalar_path():
    """Array-form schedule rows == the historical per-round scalar path
    (sample delays, then participation, then compose with the deadline
    mask) drawn from the same seed."""
    seed, R, Mloc, scale, part, dl = 3, 10, 6, 2.0, 0.5, 3.5
    sched = strag.make_schedule(seed, R, Mloc, straggler_scale=scale,
                                participation=part, deadline=dl)
    rng = np.random.default_rng(seed)
    dm = strag.DelayModel(base=1.0, scale=scale)
    for r in range(R):
        delays = dm.sample(rng, Mloc, 1)[0]
        mask = strag.participation_mask(rng, Mloc, part)
        mask = mask * strag.deadline_mask(delays, dl)
        assert np.array_equal(sched.delays[r], delays), r
        assert np.array_equal(sched.masks[r], mask), r


def test_schedule_skips_delay_draw_when_homogeneous():
    """scale=0 must not consume the delay RNG stream (the legacy driver
    only sampled delays when straggler_scale > 0)."""
    sched = strag.make_schedule(1, 4, 3, straggler_scale=0.0,
                                participation=0.5)
    assert np.array_equal(sched.delays, np.ones((4, 3)))
    rng = np.random.default_rng(1)
    for r in range(4):
        assert np.array_equal(sched.participation[r],
                              strag.participation_mask(rng, 3, 0.5)), r


def test_resume_bit_identical(setup):
    """Kill after chunk k, resume from the checkpoint: the loss trajectory
    and final params must be BIT-identical to an uninterrupted run (data
    order and the schedule are stateless in the round index)."""
    cfg, params, sfl, sched, batch_fn, key = setup
    R, C = 6, 2
    full = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn,
                             sched, key, rounds=R, mode="scan", chunk_size=C)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        # "killed" run: only the first two chunks (4 rounds) execute
        part1 = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn,
                                  sched, key, rounds=4, mode="scan",
                                  chunk_size=C, checkpointer=ck,
                                  ckpt_every=C)
        ck.wait()
        step = latest_step(d)
        assert step == 3
        restored, meta = ck.restore(params, step)
        part2 = engine.run_rounds("mu_splitfed", cfg, sfl, restored, batch_fn,
                                  sched, key, rounds=R,
                                  start_round=meta["step"] + 1, mode="scan",
                                  chunk_size=C)
    resumed_traj = np.concatenate([part1.round_loss, part2.round_loss])
    assert np.array_equal(full.round_loss, resumed_traj)
    assert maxdiff(full.params, part2.params) == 0.0


def test_gas_resume_exact_with_state(setup):
    """Stateful algorithms checkpoint their engine state alongside params
    ({'params','state'} bundle): a killed-and-resumed GAS run must be
    BIT-identical to an uninterrupted one — the activation buffer is
    restored, not re-initialized from the first resumed batch."""
    cfg, params, sfl, sched, batch_fn, key = setup
    R, C = 6, 2
    full = engine.run_rounds("gas", cfg, sfl, params, batch_fn, sched, key,
                             rounds=R, mode="scan", chunk_size=C)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        part1 = engine.run_rounds("gas", cfg, sfl, params, batch_fn, sched,
                                  key, rounds=4, mode="scan", chunk_size=C,
                                  checkpointer=ck, ckpt_every=C)
        ck.wait()
        p2, s2, meta = engine.restore_run(ck, "gas", cfg, sfl, params,
                                          batch_fn)
        assert meta["step"] == 3
        assert meta["metadata"]["has_state"] is True
        assert maxdiff(s2, part1.state) == 0.0     # buffer round-tripped
        part2 = engine.run_rounds("gas", cfg, sfl, p2, batch_fn, sched, key,
                                  rounds=R, start_round=meta["step"] + 1,
                                  state=s2, mode="scan", chunk_size=C)
    resumed = np.concatenate([part1.round_loss, part2.round_loss])
    assert np.array_equal(full.round_loss, resumed)
    assert maxdiff(full.params, part2.params) == 0.0
    assert maxdiff(full.state, part2.state) == 0.0


def test_fresh_median_rule():
    d = np.array([[1.0, 5.0, 2.0, 9.0]])
    m = strag.median_fresh_mask(d)
    assert m.tolist() == [[1.0, 0.0, 1.0, 0.0]]
