"""Substrate tests: checkpointing (atomic/async/elastic), data loaders,
optimizers, LoRA, straggler simulator, theory calculators."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, maxdiff, tiny_lm_cfg
from repro.ckpt import Checkpointer, latest_step, restore_params, save_params
from repro.core import straggler as strag
from repro.core import theory
from repro.data import FederatedLoader, SyntheticLM, dirichlet_partition
from repro.data.synthetic import SyntheticSentiment
from repro.models import init_params
from repro.optim import (adamw_init, adamw_update, make_optimizer,
                         cosine, linear_warmup)
from repro.optim.lora import apply_lora, init_lora, lora_param_count


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_bf16_exact():
    cfg = tiny_lm_cfg()          # bf16 params
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save_params(d, 7, params)
        restored, meta = restore_params(d, params)
        assert meta["step"] == 7
        assert maxdiff(params, restored) == 0.0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype


def test_ckpt_async_keep_k_and_latest():
    params = {"w": jnp.arange(10.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, jax.tree.map(lambda x: x * s, params))
        ck.wait()
        assert latest_step(d) == 4
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [3, 4]
        restored, _ = ck.restore(params)
        assert float(restored["w"][1]) == 4.0


def test_ckpt_atomicity_no_partial_dirs():
    params = {"w": jnp.zeros((1000, 100))}
    with tempfile.TemporaryDirectory() as d:
        save_params(d, 1, params)
        leftover = [x for x in os.listdir(d) if x.startswith("tmp.")]
        assert leftover == []


def test_ckpt_elastic_restore_new_sharding():
    """Restore onto a different layout (here: explicit single-device
    sharding) — the elastic-resharding path."""
    params = {"w": jnp.arange(64.0).reshape(8, 8)}
    with tempfile.TemporaryDirectory() as d:
        save_params(d, 0, params)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        restored, _ = restore_params(d, params,
                                     shardings={"w": sh})
        assert maxdiff(params, restored) == 0.0
        assert restored["w"].sharding == sh


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_loader_restart_stable():
    ds = SyntheticLM(vocab_size=64, seq_len=16, seed=3)
    parts = dirichlet_partition(np.arange(100) % 5, 4, 0.5, seed=1)
    l1 = FederatedLoader(ds, parts, batch_per_client=2, seed=9)
    l2 = FederatedLoader(ds, parts, batch_per_client=2, seed=9)
    b1, b2 = l1.round_batch(13), l2.round_batch(13)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = l1.round_batch(14)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_make_client_batches_empty_pool_falls_back():
    """Regression: a client left with no indices (sparse Dirichlet draw)
    must sample from the global pool instead of crashing rng.choice(0)."""
    from repro.data import make_client_batches
    ds = SyntheticLM(vocab_size=64, seq_len=16, seed=3)
    parts = [np.arange(10), np.array([], np.int64), np.arange(10, 20)]
    b = make_client_batches(ds, parts, round_idx=0, batch_per_client=2)
    assert b["tokens"].shape[:2] == (3, 2)
    # deterministic in (seed, round, client) like every other pool
    b2 = make_client_batches(ds, parts, round_idx=0, batch_per_client=2)
    assert np.array_equal(b["tokens"], b2["tokens"])
    with pytest.raises(ValueError, match="empty"):
        make_client_batches(ds, [np.array([], np.int64)], 0, 2)


def test_loader_subset_staging_bit_exact():
    """subset_batch(r, ids) == round_batch(r)[ids] bit for bit (the
    sparse engine's O(K) staging path — per-client RNG keyed on (seed,
    round, client)), including clients on the empty-pool fallback and
    repeated/unsorted ids; the per-client pools are resolved once and
    cached on the loader."""
    ds = SyntheticLM(vocab_size=64, seq_len=16, seed=3)
    parts = [np.arange(10), np.array([], np.int64), np.arange(10, 20),
             np.arange(20, 24)]
    loader = FederatedLoader(ds, parts, batch_per_client=2, seed=9)
    assert loader.pools is loader.pools         # resolved once, cached
    for r in (0, 7):
        full = {k: np.asarray(v) for k, v in loader.round_batch(r).items()}
        for ids in ([2, 0], [1, 1, 3], np.array([3])):
            sub = loader.subset_batch(r, ids)
            idx = np.asarray(ids)
            for k in full:
                assert np.array_equal(full[k][idx], sub[k]), (r, k)


def test_synthetic_lm_learnable_structure():
    ds = SyntheticLM(vocab_size=64, seq_len=256, seed=0)
    s = ds.sample(0)
    # bigram structure: successors are constrained -> repeated bigrams
    pairs = set(zip(s[:-1].tolist(), s[1:].tolist()))
    assert len(pairs) < 0.9 * (len(s) - 1)


def test_sentiment_labels_verbalized():
    ds = SyntheticSentiment(vocab_size=128, seq_len=32, seed=0)
    b = ds.batch(np.arange(8))
    last = b["tokens"][:, -1]
    assert ((last == 126) | (last == 127)).all()
    assert (b["labels"][:, -2] == b["tokens"][:, -1]).all()


# ---------------------------------------------------------------------------
# optim / lora
# ---------------------------------------------------------------------------

def test_adamw_descends():
    params = {"w": jnp.full((32,), 5.0)}
    grad_fn = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))
    st = adamw_init(params)
    p = params
    for _ in range(100):
        p, st = adamw_update(p, grad_fn(p), st, lr=0.1)
    assert float(jnp.sum(jnp.square(p["w"]))) < 1.0


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam"])
def test_optimizer_factory(name):
    init, update = make_optimizer(name)
    params = {"w": jnp.ones((4,))}
    st = init(params)
    p, st = update(params, {"w": jnp.ones((4,))}, st, 0.1)
    assert float(p["w"][0]) < 1.0


def test_schedules():
    f = linear_warmup(1.0, 10)
    assert float(f(0)) < float(f(9)) <= 1.0
    g = cosine(1.0, 5, 100)
    assert float(g(99)) < float(g(10))


def test_lora_only_adapters_change_effective_weights():
    cfg = tiny_lm_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    lora = init_lora(cfg, params, rank=2, key=jax.random.PRNGKey(1))
    assert lora_param_count(lora) > 0
    eff = apply_lora(params, lora)         # B=0 -> identity at init
    assert maxdiff(eff, params) == 0.0
    lora2 = jax.tree.map(lambda x: x + 0.1, lora)
    eff2 = apply_lora(params, lora2)
    assert maxdiff(eff2, params) > 0.0


# ---------------------------------------------------------------------------
# straggler model / theory
# ---------------------------------------------------------------------------

def test_tau_planner():
    assert strag.plan_tau(10.0, 1.0) == 10
    assert strag.plan_tau(0.5, 1.0) == 1
    assert strag.plan_tau(1e9, 1.0, tau_max=64) == 64


def test_mu_splitfed_round_time_overlap():
    """Server τ steps overlap client compute: round time = max(...)."""
    ct = np.array([1.0, 5.0])
    m = np.ones(2, np.float32)
    assert strag.round_time_mu_splitfed(ct, m, t_server=1.0, tau=3) == 5.0
    assert strag.round_time_mu_splitfed(ct, m, t_server=2.0, tau=4) == 8.0
    assert strag.round_time_vanilla(ct, m, t_server=1.0) == 6.0


def test_simulated_speedup_under_stragglers():
    """End-to-end Eq. 12: τ-planned MU-SplitFed total time ≈ T0·t_server,
    beating vanilla's T0·t_straggler."""
    rng = np.random.default_rng(0)
    delays = strag.DelayModel(base=1.0, scale=3.0).sample(rng, 8, 200)
    masks = np.ones_like(delays, np.float32)
    t_server = 0.25
    t_strag = float(delays.max(1).mean())
    tau = strag.plan_tau(t_strag, t_server)
    T0 = 200
    t_vanilla = strag.simulate_total_time("vanilla", delays, masks, t_server,
                                          1, rounds_needed=T0)
    t_mu = strag.simulate_total_time("mu_splitfed", delays, masks, t_server,
                                     tau, rounds_needed=max(T0 // tau, 1))
    assert t_mu < 0.5 * t_vanilla


def test_theory_bound_terms_positive_and_rate_matches():
    b = theory.mu_splitfed_bound(F0=1.0, L=1.0, T=100, tau=4, M=8,
                                 d_c=100, d_s=10_000, sigma_c=1.0,
                                 sigma_s=1.0, eps_het=1.0, lam=1e-4)
    assert all(v > 0 for k, v in b.items() if k not in ("eta", "eta_g"))
    r1 = theory.mu_splitfed_rate(1, 1, 100, 1, 8, 10_100, 1, 1, 1)
    r4 = theory.mu_splitfed_rate(1, 1, 100, 4, 8, 10_100, 1, 1, 1)
    assert r4 < r1


def test_comm_complexity_table2():
    d, tau, M, K, eps = 10**6, 8, 10, 5, 0.1
    c1 = theory.comm_complexity("mu_splitfed_tau1", d, tau, M, K, eps)
    ct = theory.comm_complexity("mu_splitfed", d, tau, M, K, eps)
    cd = theory.comm_complexity("mu_splitfed_tau_to_d", d, tau, M, K, eps)
    assert ct == pytest.approx(c1 / tau)      # linear reduction in tau
    assert cd == pytest.approx(c1 / d)        # dimension-free limit
