"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device setting belongs exclusively to repro.launch.dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def jax_sanitizers(monkeypatch):
    """Runtime backstop for repro.analysis's host-sync rule (opt in with
    ``pytestmark = pytest.mark.usefixtures("jax_sanitizers")``).

    Two sanitizers for the duration of the test:

    * ``jax_numpy_rank_promotion="raise"`` — implicit rank promotion in
      any jnp op becomes an error instead of a silent broadcast;
    * every executable minted by the engine's ``_cached_jit`` registry
      dispatches under ``jax.transfer_guard("disallow")`` — an argument
      reaching the jit boundary that is not already device-committed
      (stray numpy row, python scalar) trips an implicit host-to-device
      transfer error. Host staging around the call (jnp.asarray uploads,
      the per-chunk np.asarray flush) is explicit and stays legal, so
      this pins exactly the invariant: no *implicit* transfers inside
      the engine's scan/stream loop.
    """
    from repro.core import engine as _engine
    orig_cached_jit = _engine._cached_jit

    def guarded_cached_jit(algo, mode, cfg, sfl, build):
        fn = orig_cached_jit(algo, mode, cfg, sfl, build)

        def dispatch(*args, **kwargs):
            with jax.transfer_guard("disallow"):
                return fn(*args, **kwargs)
        return dispatch

    monkeypatch.setattr(_engine, "_cached_jit", guarded_cached_jit)
    old = jax.config.jax_numpy_rank_promotion or "allow"
    jax.config.update("jax_numpy_rank_promotion", "raise")
    try:
        yield
    finally:
        jax.config.update("jax_numpy_rank_promotion", old)


def tiny_lm_cfg(**kw):
    """A minimal dense config for algorithm tests (fast compiles)."""
    from repro.configs import get_config
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=64, max_seq_len=64)
    base.update(kw)
    return get_config("olmo-1b", smoke=True).replace(**base)


def lm_batch(key, cfg, B, S, M=None):
    shape = (M, B, S) if M else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
