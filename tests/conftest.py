"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device setting belongs exclusively to repro.launch.dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_lm_cfg(**kw):
    """A minimal dense config for algorithm tests (fast compiles)."""
    from repro.configs import get_config
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=64, max_seq_len=64)
    base.update(kw)
    return get_config("olmo-1b", smoke=True).replace(**base)


def lm_batch(key, cfg, B, S, M=None):
    shape = (M, B, S) if M else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
