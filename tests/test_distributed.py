"""Multi-device correctness (subprocess: tests must not pollute this
process's device count). Verifies that a sharded MU-SplitFed round on an
8-device mesh produces the same numbers as the single-device run, and that
the dry-run machinery lowers/compiles on small meshes."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import SFLConfig, get_config
from repro.core.splitfed import mu_splitfed_round
from repro.models import init_params, untie_params
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_cell, lower_cell
from repro.configs.base import ShapeConfig

cfg = get_config("olmo-1b", smoke=True).replace(dtype="float32")
key = jax.random.PRNGKey(0)
params = untie_params(cfg, init_params(cfg, key))
M = 4
batches = {"tokens": jax.random.randint(key, (M, 2, 16), 0, cfg.vocab_size)}
batches["labels"] = batches["tokens"]
mask = jnp.ones((M,), jnp.float32)
sfl = SFLConfig(n_clients=M, tau=2, cut_units=1)

# single-device reference
p_ref, _ = mu_splitfed_round(cfg, sfl, params, batches, mask, key)

# sharded: M over data, TP over model
mesh = make_mesh((4, 2), ("data", "model"))
bsh = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P("data"))),
                   batches)
p_sh, _ = jax.jit(lambda p, b, m, k: mu_splitfed_round(cfg, sfl, p, b, m, k)
                  )(params, bsh, mask, key)
diff = max(float(jnp.max(jnp.abs(a - b)))
           for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
assert diff < 2e-5, f"sharded round diverges: {diff}"

# dry-run machinery on a small mesh (train + decode cells)
for shape in (ShapeConfig("t", 32, 8, "train"), ShapeConfig("d", 64, 8, "decode")):
    cell = build_cell("olmo-1b", shape, mesh, smoke=True,
                      sfl=sfl if shape.kind == "train" else None)
    lower_cell(cell).compile()

# fused multi-round cell (perf ladder v5): 2 rounds in one scan dispatch
from repro.launch.steps import build_train_multi_cell
mcell = build_train_multi_cell("olmo-1b", ShapeConfig("t", 32, 8, "train"),
                               mesh, smoke=True, sfl=sfl, rounds_per_chunk=2)
lower_cell(mcell).compile()
print("DISTRIBUTED_OK", diff)
"""


@pytest.mark.slow
def test_sharded_round_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, cwd="/root/repo")
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
