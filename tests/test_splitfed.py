"""MU-SplitFed round semantics: mode equivalences, τ=1 == vanilla,
participation masking, convergence on a tiny task."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import lm_batch, maxdiff, tiny_lm_cfg
from repro.configs import SFLConfig
from repro.core.baselines import vanilla_splitfed_round
from repro.core.splitfed import mu_splitfed_round
from repro.models import init_params, untie_params

M = 4


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    batches = lm_batch(jax.random.PRNGKey(9), cfg, 2, 16, M=M)
    sfl = SFLConfig(n_clients=M, tau=3, cut_units=1)
    return cfg, params, batches, sfl


def test_parallel_equals_sequential(setup):
    cfg, params, batches, sfl = setup
    mask = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(7)
    p1, m1 = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                               client_mode="parallel")
    p2, m2 = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                               client_mode="sequential")
    assert maxdiff(p1, p2) < 1e-5
    assert jnp.allclose(m1.loss, m2.loss, atol=1e-5)


def test_dense_equals_seed_replay_f32(setup):
    """Eq. 7 dense aggregation == compressed seed-replay aggregation (exact
    in f32 up to summation order)."""
    cfg, params, batches, sfl = setup
    mask = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(7)
    p1, _ = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                              aggregation="dense")
    p2, _ = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                              aggregation="seed_replay")
    assert maxdiff(p1, p2) < 1e-5


def test_tau1_equals_vanilla_splitfed(setup):
    """Vanilla SplitFed is exactly MU-SplitFed at τ=1 (paper §5 baseline)."""
    cfg, params, batches, _ = setup
    sfl1 = SFLConfig(n_clients=M, tau=1, cut_units=1)
    sfl9 = SFLConfig(n_clients=M, tau=9, cut_units=1)  # tau ignored by vanilla
    mask = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(11)
    p1, _ = mu_splitfed_round(cfg, sfl1, params, batches, mask, rk)
    p2, _ = vanilla_splitfed_round(cfg, sfl9, params, batches, mask, rk)
    assert maxdiff(p1, p2) == 0.0


def test_inactive_clients_do_not_contribute(setup):
    """With only client 0 active, the update must be independent of the
    other clients' data."""
    cfg, params, batches, sfl = setup
    mask = jnp.zeros((M,), jnp.float32).at[0].set(1.0)
    rk = jax.random.PRNGKey(13)
    p1, _ = mu_splitfed_round(cfg, sfl, params, batches, mask, rk)
    scrambled = jax.tree.map(
        lambda a: a.at[1:].set(jnp.flip(a[1:], axis=-1)), batches)
    p2, _ = mu_splitfed_round(cfg, sfl, params, scrambled, mask, rk)
    assert maxdiff(p1, p2) < 1e-6


def test_tau_amortizes_progress(setup):
    """More server steps per round (higher τ) should move the server-side
    parameters further per communication round."""
    cfg, params, batches, _ = setup
    mask = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(17)

    def server_movement(tau):
        sfl = SFLConfig(n_clients=M, tau=tau, cut_units=1,
                        lr_server=1e-3, lr_client=5e-4)
        p, _ = mu_splitfed_round(cfg, sfl, params, batches, mask, rk)
        from repro.models import split_params
        _, s0 = split_params(cfg, params, 1)
        _, s1 = split_params(cfg, p, 1)
        return sum(float(jnp.sum(jnp.square(a - b)))
                   for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)))

    assert server_movement(8) > server_movement(1)


def test_loss_decreases_over_rounds():
    cfg = tiny_lm_cfg(dtype="float32", vocab_size=32)
    key = jax.random.PRNGKey(1)
    params = untie_params(cfg, init_params(cfg, key))
    sfl = SFLConfig(n_clients=2, tau=2, cut_units=1,
                    lr_server=5e-3, lr_client=1e-3, lr_global=1.0)
    batches = lm_batch(jax.random.PRNGKey(2), cfg, 2, 16, M=2)
    mask = jnp.ones((2,), jnp.float32)
    round_fn = jax.jit(lambda p, k: mu_splitfed_round(
        cfg, sfl, p, batches, mask, k))
    losses = []
    for r in range(30):
        params, m = round_fn(params, jax.random.fold_in(key, r))
        losses.append(float(m.loss.mean()))
    assert (sum(losses[-5:]) / 5) < (sum(losses[:5]) / 5), losses
