"""Event-driven semi-async subsystem (core/events.py + engine mode='async'):
timeline compilation semantics (quorum commits, staleness fold-in,
determinism), the sync-equivalence gate (quorum=all + discount 1.0
reproduces mode='scan'), bit-identical checkpoint resume with the record
store, and the adaptive-τ controller over async windows."""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from conftest import maxdiff, tiny_lm_cfg
from repro.ckpt import Checkpointer
from repro.configs import SFLConfig
from repro.core import engine, events
from repro.core import straggler as strag
from repro.core.population import ClientPopulation, Cohort, DelayModel
from repro.models import init_params, untie_params

M = 4
ROUNDS = 8

# runtime sanitizers on the whole module: rank-promotion errors + the
# transfer guard around jit'd engine dispatches (see conftest)
pytestmark = pytest.mark.usefixtures("jax_sanitizers")


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0)
    # the acceptance regime: stragglers AND partial participation
    sched = strag.make_schedule(0, ROUNDS, M, straggler_scale=2.0,
                                participation=0.5, t_server=0.1, t_comm=0.2)

    def batch_fn(r):
        k = jax.random.fold_in(jax.random.PRNGKey(99), r)
        t = jax.random.randint(k, (M, 2, 16), 0, cfg.vocab_size)
        return {"tokens": t, "labels": t}

    return cfg, params, sfl, sched, batch_fn, key


def tiered_pop(fast=3, slow=1, base_slow=4.0):
    return ClientPopulation(cohorts=(
        Cohort(name="fast", n=fast, delay=DelayModel(base=0.3, scale=0.0)),
        Cohort(name="slow", n=slow,
               delay=DelayModel(base=base_slow, scale=0.0)),
    ))


# ---------------------------------------------------------------------------
# acceptance gate: async == sync at full quorum, no discount
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("aggregation", ["dense", "seed_replay"])
def test_async_matches_scan_at_full_quorum(setup, aggregation):
    """quorum=0 (wait for all) + staleness_discount=1.0: mode='async' must
    reproduce mode='scan' — loss trajectory <=1e-5 and matching final
    params — for mu_splitfed under stragglers + partial participation.
    (Against seed_replay aggregation the async step is the identical
    computation, so the match is exact; dense differs only by the
    aggregation algebra, <=1e-5.)"""
    cfg, params, sfl, sched, batch_fn, key = setup
    sc = engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched,
                           key, rounds=ROUNDS, mode="scan", chunk_size=3,
                           aggregation=aggregation)
    asy = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=ROUNDS, mode="async",
                            chunk_size=3)
    assert asy.round_loss.shape == (ROUNDS,)
    assert np.max(np.abs(sc.round_loss - asy.round_loss)) <= 1e-5
    assert maxdiff(sc.params, asy.params) <= 1e-5
    if aggregation == "seed_replay":        # literally the same records
        assert np.array_equal(sc.round_loss, asy.round_loss)
        assert maxdiff(sc.params, asy.params) == 0.0


def test_async_requires_capable_algorithm(setup):
    cfg, params, sfl, sched, batch_fn, key = setup
    with pytest.raises(ValueError, match="async_round_fn"):
        engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched,
                          key, rounds=2, mode="async")
    # the record store IS the seed-replay wire format — anything else is
    # rejected, not silently ignored
    with pytest.raises(ValueError, match="not replayable"):
        engine.get_algorithm("async_mu_splitfed", aggregation="dense")
    with pytest.raises(ValueError, match="parallel"):
        engine.get_algorithm("async_mu_splitfed", client_mode="sequential")


# ---------------------------------------------------------------------------
# timeline compilation semantics
# ---------------------------------------------------------------------------

def test_timeline_full_quorum_is_the_sync_barrier():
    sched = strag.make_schedule(0, 6, 4, straggler_scale=1.5,
                                participation=0.5, t_server=0.1)
    tl = events.compile_timeline(sched, 6, quorum=0, discount=1.0, tau=2)
    assert np.array_equal(tl.start_mask, sched.masks)
    act = sched.masks.sum(1)
    want = np.where(sched.masks > 0, 1.0 / act[:, None], 0.0)
    assert np.allclose(tl.apply_w, want)
    assert (tl.staleness == 0).all()
    assert np.array_equal(tl.commit_idx, tl.round_of_origin)


def test_timeline_quorum_commits_at_kth_arrival_and_folds_stragglers():
    """K=3 of {3 fast, 1 slow}: commits pace at the fast tier; the slow
    client's contribution is not dropped — it folds into a later commit
    with staleness = commits missed and a discount**s weight, and the
    client is busy (no fresh start) until it delivers."""
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, 12, population=pop, t_server=0.1)
    tl = events.compile_timeline(sched, 12, quorum=3, discount=0.5, tau=2)
    # fast tier paces every commit: duration = max(0.3, tau*t_server)
    assert np.allclose(tl.durations, 0.3)
    assert np.allclose(tl.quorum_wait, 0.3)
    # slow client (id 3) delivers at 1.0 = 3 commits late, then restarts
    slow = tl.client_id == 3
    assert (tl.staleness[slow & (tl.commit_idx >= 0)] == 3).all()
    # busy until delivery: no fresh start while its work is in flight
    assert tl.start_mask[0, 3] == 1.0
    assert (tl.start_mask[1:3, 3] == 0.0).all()
    # discounted weight: 0.5**3 against three fresh (0.5**0) contributions
    v = int(tl.commit_idx[np.flatnonzero(slow)[0]])
    w = tl.apply_w[v]
    assert w[3] == pytest.approx(0.125 / (3 + 0.125))
    assert np.isclose(w.sum(), 1.0)
    # flat event view is globally arrival-ordered
    assert (np.diff(tl.arrival_time) >= 0).all()
    # cohort ids come from the population
    assert set(tl.cohort_id[tl.client_id <= 2]) == {0}
    assert set(tl.cohort_id[tl.client_id == 3]) == {1}


def test_timeline_deterministic_per_seed():
    pop = tiered_pop()
    kw = dict(quorum=3, discount=0.7, tau=2)
    a = events.compile_timeline(
        strag.make_schedule(5, 10, population=pop, t_server=0.1), 10, **kw)
    b = events.compile_timeline(
        strag.make_schedule(5, 10, population=pop, t_server=0.1), 10, **kw)
    for f in dataclasses.fields(a):
        va = getattr(a, f.name)
        if isinstance(va, np.ndarray):
            assert np.array_equal(va, getattr(b, f.name)), f.name
    c = events.compile_timeline(
        strag.make_schedule(6, 10, 4, straggler_scale=1.0, t_server=0.1),
        10, **kw)
    assert not np.array_equal(a.apply_w, c.apply_w)


def test_timeline_prefix_stable_under_tau_change():
    """Recompiling with a piecewise-τ array that agrees on the first v
    versions must reproduce the first v rows exactly — what lets a
    controller re-plan τ without rewriting the executed past."""
    sched = strag.make_schedule(1, 8, 4, straggler_scale=1.0, t_server=0.3)
    a = events.compile_timeline(sched, 8, quorum=2, discount=0.5, tau=2)
    taus = np.full(8, 2, np.int64)
    taus[4:] = 6
    b = events.compile_timeline(sched, 8, quorum=2, discount=0.5, tau=taus)
    assert np.array_equal(a.start_mask[:4], b.start_mask[:4])
    assert np.array_equal(a.apply_w[:4], b.apply_w[:4])
    assert np.array_equal(a.commit_times[:4], b.commit_times[:4])
    # the re-planned tail actually changed the pacing
    assert (b.durations[4:] >= 6 * 0.3 - 1e-12).all()


def test_quorum_round_time_single_row():
    delays = np.array([0.2, 0.5, 1.0, 9.0])
    mask = np.array([1.0, 1.0, 1.0, 1.0])
    assert events.quorum_round_time(delays, mask, 0.1, 2, quorum=3) \
        == pytest.approx(1.0)
    assert events.quorum_round_time(delays, mask, 0.1, 2, quorum=0) \
        == pytest.approx(9.0)
    # the tau*t_server floor (unbalanced-update overlap)
    assert events.quorum_round_time(delays, mask, 0.4, 8, quorum=3) \
        == pytest.approx(3.2)
    # uplink scales enter the arrival, per client
    assert events.quorum_round_time(
        delays, mask, 0.1, 2, quorum=4, t_comm=0.1,
        t_comm_scale=np.array([1.0, 1.0, 1.0, 10.0])) == pytest.approx(10.0)


def test_resize_store_pads_and_truncates():
    sfl = SFLConfig(n_clients=3, tau=4, n_perturbations=2)
    store = events.init_store(sfl)
    grown = events.resize_store(store, 6)
    assert grown["srv_keys"].shape == (3, 6, 2, 2)
    assert grown["srv_coeffs"].shape == (3, 6, 2)
    shrunk = events.resize_store(grown, 2)
    assert shrunk["srv_keys"].shape == (3, 2, 2, 2)
    assert events.resize_store(store, 4) is store


# ---------------------------------------------------------------------------
# end-to-end semi-async: wall-clock + resume + adaptive tau
# ---------------------------------------------------------------------------

def test_async_quorum_beats_sync_wall_clock(setup):
    """On a tiered fleet, K<M commits pace at the fast tier: the async run
    must finish the same number of server versions in far less simulated
    time than the synchronous barrier."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=4.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    base = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                     lr_client=1e-3, lr_global=1.0, population=pop)
    sync = engine.run_rounds("mu_splitfed", cfg, base, params, batch_fn,
                             sched, key, rounds=ROUNDS, mode="scan")
    sfl = dataclasses.replace(base, quorum=3, staleness_discount=0.5)
    asy = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=ROUNDS, mode="async")
    assert asy.sim_time < sync.sim_time / 3
    assert np.isfinite(asy.round_loss).all()


def test_async_resume_bit_identical(setup):
    """Kill mid-run, restore the {'params', record-store} bundle, resume:
    trajectory and final params/state must be BIT-identical — the compiled
    timeline is deterministic and sliced from version 0, and the in-flight
    buffer rides in the checkpoint."""
    cfg, params, sfl0, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sfl = dataclasses.replace(sfl0, population=pop, straggler_rate=0.0,
                              participation=1.0, quorum=3,
                              staleness_discount=0.5)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    R, C = ROUNDS, 2
    full = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                             sched, key, rounds=R, mode="async", chunk_size=C)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        part1 = engine.run_rounds("async_mu_splitfed", cfg, sfl, params,
                                  batch_fn, sched, key, rounds=4,
                                  mode="async", chunk_size=C,
                                  checkpointer=ck, ckpt_every=C)
        ck.wait()
        p2, s2, meta = engine.restore_run(ck, "async_mu_splitfed", cfg, sfl,
                                          params, batch_fn)
        assert meta["step"] == 3
        assert meta["metadata"]["has_state"] is True
        assert maxdiff(s2, part1.state) == 0.0     # store round-tripped
        part2 = engine.run_rounds("async_mu_splitfed", cfg, sfl, p2,
                                  batch_fn, sched, key, rounds=R,
                                  start_round=meta["step"] + 1, state=s2,
                                  mode="async", chunk_size=C)
    resumed = np.concatenate([part1.round_loss, part2.round_loss])
    assert np.array_equal(full.round_loss, resumed)
    assert maxdiff(full.params, part2.params) == 0.0
    assert maxdiff(full.state, part2.state) == 0.0


def test_async_controller_resume_replays_tau_history(setup):
    """A resumed adaptive-τ async run must recompile the timeline PREFIX
    with the τ that actually executed (checkpoint metadata
    'tau_per_version' -> run_rounds tau_history) — compiling the prefix
    with the final τ would shift every commit time and hand the restored
    record store inconsistent apply weights. On a stationary fleet the
    resumed trajectory is then bit-identical to the uninterrupted run
    (the skipped first re-plan is a no-op once τ has settled)."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    sfl = SFLConfig(n_clients=M, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop,
                    quorum=3, staleness_discount=0.5)
    full = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                             sched, key, rounds=ROUNDS, mode="async",
                             chunk_size=2,
                             controller=engine.AdaptiveTau(tau_max=8))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ctl = engine.AdaptiveTau(tau_max=8)
        engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                          sched, key, rounds=6, mode="async", chunk_size=2,
                          controller=ctl, checkpointer=ck, ckpt_every=2)
        ck.wait()
        from repro.ckpt import read_meta
        ctl2 = engine.AdaptiveTau(tau_max=8)
        sfl2 = engine.apply_resume_overrides(sfl, read_meta(d), ctl2)
        assert sfl2.tau > 1                        # controller re-planned
        p2, s2, meta = engine.restore_run(ck, "async_mu_splitfed", cfg,
                                          sfl2, params, batch_fn)
        hist = meta["metadata"]["tau_per_version"]
        assert hist[:2] == [1, 1]                  # the τ=1 prefix survives
        part2 = engine.run_rounds("async_mu_splitfed", cfg, sfl2, p2,
                                  batch_fn, sched, key, rounds=ROUNDS,
                                  start_round=meta["step"] + 1, state=s2,
                                  mode="async", chunk_size=2,
                                  controller=ctl2, tau_history=hist)
    assert np.array_equal(full.round_loss[meta["step"] + 1:],
                          part2.round_loss)
    assert maxdiff(full.params, part2.params) == 0.0
    assert np.array_equal(full.tau_per_round[meta["step"] + 1:],
                          part2.tau_per_round)


def test_adaptive_tau_consumes_async_window(setup):
    """Over async windows AdaptiveTau observes the QUORUM wait (K-th
    arrival), not the max active delay: with 3 fast clients at 0.3s and a
    4s straggler, quorum=3 plans τ = 0.3/t_server, not 4/t_server."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=4.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    sfl = SFLConfig(n_clients=M, tau=1, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop, quorum=3)
    ctl = engine.AdaptiveTau(tau_max=64)
    res = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=ROUNDS, mode="async",
                            chunk_size=2, controller=ctl)
    want = strag.plan_tau(0.3, 0.1)                # = 3, not 40
    assert [t for _, t in ctl.trace] == [want] * 3
    assert res.tau_per_round.tolist() == [1, 1] + [want] * (ROUNDS - 2)
    # re-planned τ re-paced the committed versions (timeline recompiled)
    assert res.round_times[-1] == pytest.approx(max(0.3, want * 0.1))


# ---------------------------------------------------------------------------
# sparse streaming timeline: V=0 regression, densify == dense, the chunked
# stream, ring geometry, and the sparse engine path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compile_fn", [
    events.compile_timeline,
    lambda *a, **k: events.compile_sparse_timeline(*a, **k).densify()])
def test_compile_timeline_v0_is_empty_not_a_crash(compile_fn):
    """Regression: V=0 (and the no-events path it implies) used to crash
    np.stack on an empty mask list; both backends must return empty,
    well-shaped rows."""
    sched = strag.make_schedule(0, 4, M, straggler_scale=1.0, t_server=0.1)
    tl = compile_fn(sched, 0, quorum=2, discount=0.5, tau=2)
    assert tl.start_mask.shape == (0, M)
    assert tl.apply_w.shape == (0, M)
    assert tl.commit_times.shape == (0,)
    assert tl.client_id.shape == (0,)
    assert tl.tau_per_version.shape == (0,)


@pytest.mark.parametrize("quorum,discount", [(0, 1.0), (3, 1.0), (3, 0.5),
                                             (2, 0.25)])
def test_sparse_densify_matches_dense(setup, quorum, discount):
    """At exact geometry (k_max = capacity = M) the heap DES reproduces the
    dense compiler field-for-field — the refactor's bit-equivalence gate."""
    _, _, _, sched, _, _ = setup
    taus = 1 + (np.arange(10) % 3)
    dense = events.compile_timeline(sched, 10, quorum=quorum,
                                    discount=discount, tau=taus)
    got = events.compile_sparse_timeline(sched, 10, quorum=quorum,
                                         discount=discount, tau=taus)
    for f in dataclasses.fields(dense):
        va = getattr(dense, f.name)
        if isinstance(va, np.ndarray):
            assert np.array_equal(va, getattr(got.densify(), f.name)), f.name


def test_stream_chunked_take_and_skip_match_compile(setup):
    """TimelineStream is the incremental view of the same DES: chunked
    take() concatenates to the one-shot rows, and skip(r0) replays the
    prefix so take() resumes bit-identically (what checkpoint resume and
    controller re-plans rely on)."""
    _, _, _, sched, _, _ = setup
    V, kw = 12, dict(quorum=3, discount=0.5, taus=2, k_max=M, capacity=M)
    whole = events.TimelineStream(sched, V, **kw).take(V)
    st = events.TimelineStream(sched, V, **kw)
    chunks = [st.take(5), st.take(5), st.take(2)]
    for f in whole._fields:
        want = getattr(whole, f)
        got = np.concatenate([getattr(c, f) for c in chunks])
        assert np.array_equal(want, got), f
    skipped = events.TimelineStream(sched, V, **kw)
    skipped.skip(7)
    tail = skipped.take(5)
    for f in whole._fields:
        assert np.array_equal(getattr(whole, f)[7:], getattr(tail, f)), f


def test_bounded_ring_evicts_oldest_and_truncates_to_k_max():
    """Forced-tight geometry: starts/applies clip at the k_max batch
    width (overflow counted as skipped / deferred, never silent) and a
    full ring evicts the oldest-started in-flight record."""
    # slow tier FIRST: ids 0-1 are admitted at v0, park in ring slots for
    # ~10 commits, and get evicted when fresh fast starts need the space
    pop = ClientPopulation(cohorts=(
        Cohort(name="slow", n=2, delay=DelayModel(base=8.0, scale=0.0)),
        Cohort(name="fast", n=6, delay=DelayModel(base=0.3, scale=0.0)),
    ))
    sched = strag.make_schedule(0, 8, population=pop, t_server=0.1)
    st = events.TimelineStream(sched, 16, quorum=1, discount=0.5, taus=1,
                               k_max=3, capacity=3)
    rows = st.take(16)
    assert np.all(rows.started <= 3) and np.all(rows.applied <= 3)
    assert rows.skipped.sum() > 0          # idle fast tier exceeds k_max
    assert rows.evicted.sum() > 0          # slow tier outlives the ring
    in_flight = (rows.started.sum() - rows.applied.sum()
                 - rows.evicted.sum())
    assert 0 <= in_flight <= 3
    # pad conventions the device step relies on: dropped scatter slot,
    # zero-weight clamped gather
    assert np.all(rows.start_slot[rows.start_client < 0] == 3)
    assert np.all(rows.apply_w[rows.apply_client < 0] == 0.0)
    # ragged rows pad to the fixed (C, k_max) widths the device scans
    assert rows.start_client.shape == (16, 3)
    assert rows.apply_client.shape == (16, 3)


def test_resolve_store_geometry_autos():
    mk = lambda **kw: SFLConfig(n_clients=kw.pop("M"), **kw)
    # quorum=0: both collapse to M — the dense one-slot-per-client layout
    assert events.resolve_store_geometry(mk(M=7)) == (7, 7)
    # small fleet: the 4x-quorum floor caps at M (no truncation => the
    # bit-equivalence regime)
    assert events.resolve_store_geometry(mk(M=4, quorum=2)) == (4, 4)
    # fleet scale: k = 4*K (floor 16), ring = 8 commit batches
    assert events.resolve_store_geometry(mk(M=10_000, quorum=64)) \
        == (256, 2048)
    assert events.resolve_store_geometry(mk(M=10_000, quorum=2)) == (16, 128)
    # explicit overrides win but never exceed M, and cap >= k
    assert events.resolve_store_geometry(
        mk(M=100, quorum=8, k_max=10, ring_capacity=5)) == (10, 10)


def test_sparse_store_leading_dim_is_ring_capacity():
    sfl = SFLConfig(n_clients=100, tau=2, n_perturbations=2, quorum=4,
                    timeline="sparse")
    _, cap = events.resolve_store_geometry(sfl)
    store = events.init_store(sfl)
    assert cap == min(100, 8 * 16)                 # auto: 8 batches of 16
    assert store["srv_keys"].shape[0] == cap
    dense_store = events.init_store(dataclasses.replace(sfl,
                                                        timeline="dense"))
    assert dense_store["srv_keys"].shape[0] == 100


def test_sparse_timeline_rejects_sync_modes(setup):
    cfg, params, _, sched, batch_fn, key = setup
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, timeline="sparse")
    with pytest.raises(ValueError, match="mode='async'"):
        engine.run_rounds("mu_splitfed", cfg, sfl, params, batch_fn, sched,
                          key, rounds=2, mode="scan")
    bad = SFLConfig(n_clients=M, tau=2, cut_units=1, timeline="ring")
    with pytest.raises(ValueError, match="'dense'|'sparse'"):
        engine.run_rounds("mu_splitfed", cfg, bad, params, batch_fn, sched,
                          key, rounds=2, mode="scan")


def test_sparse_engine_matches_dense_async(setup):
    """The tentpole gate: the streamed (C, K) gather/scatter execution
    reproduces the dense async trajectory (<=1e-5; commit pacing exactly)
    on a tiered fleet with a real quorum + discount."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    base = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                     lr_client=1e-3, lr_global=1.0, population=pop,
                     quorum=3, staleness_discount=0.5)
    dense = engine.run_rounds("async_mu_splitfed", cfg, base, params,
                              batch_fn, sched, key, rounds=ROUNDS,
                              mode="async", chunk_size=2)
    sp = engine.run_rounds("async_mu_splitfed", cfg,
                           dataclasses.replace(base, timeline="sparse"),
                           params, batch_fn, sched, key, rounds=ROUNDS,
                           mode="async", chunk_size=2)
    assert np.max(np.abs(dense.round_loss - sp.round_loss)) <= 1e-5
    assert np.array_equal(dense.round_times, sp.round_times)
    assert maxdiff(dense.params, sp.params) <= 1e-5


def test_sparse_resume_bit_identical(setup):
    """Checkpoint resume under timeline='sparse': the stream's skip()
    prefix replay plus the restored ring store reproduce the
    uninterrupted run bit for bit."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop,
                    quorum=3, staleness_discount=0.5, timeline="sparse")
    full = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                             sched, key, rounds=ROUNDS, mode="async",
                             chunk_size=2)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        part1 = engine.run_rounds("async_mu_splitfed", cfg, sfl, params,
                                  batch_fn, sched, key, rounds=4,
                                  mode="async", chunk_size=2,
                                  checkpointer=ck, ckpt_every=2)
        ck.wait()
        p2, s2, meta = engine.restore_run(ck, "async_mu_splitfed", cfg, sfl,
                                          params, batch_fn)
        assert meta["step"] == 3
        assert maxdiff(s2, part1.state) == 0.0     # ring store round-trips
        part2 = engine.run_rounds("async_mu_splitfed", cfg, sfl, p2,
                                  batch_fn, sched, key, rounds=ROUNDS,
                                  start_round=meta["step"] + 1, state=s2,
                                  mode="async", chunk_size=2)
    resumed = np.concatenate([part1.round_loss, part2.round_loss])
    assert np.array_equal(full.round_loss, resumed)
    assert maxdiff(full.params, part2.params) == 0.0
    assert maxdiff(full.state, part2.state) == 0.0


# ---------------------------------------------------------------------------
# fleet-scale hot path: cohort-indexed idle sets + O(K) subset staging
# ---------------------------------------------------------------------------

def test_cohort_index_matches_flatnonzero_reference():
    """The cohort-bucketed idle index admits exactly
    ``flatnonzero((mask > 0) & ~busy)[:k_max]`` in ascending client order
    and counts every candidate — over randomized fleets, masks, k_max,
    busy churn, and cohort boundaries (exercising virgin-range walks,
    recycled-heap pops, stale entries, and batch finish)."""
    from repro.core.population import AvailRow
    rng = np.random.default_rng(0)
    for trial in range(40):
        M_ = int(rng.integers(2, 40))
        n_cuts = int(rng.integers(0, min(4, M_ - 1) + 1))
        cuts = (sorted(rng.choice(np.arange(1, M_), size=n_cuts,
                                  replace=False).tolist())
                if n_cuts else [])
        bounds = list(zip([0] + cuts, cuts + [M_]))
        idx = events._CohortIdleIndex(bounds)
        busy = np.zeros(M_, bool)
        for step in range(12):
            mask = (rng.random(M_)
                    < rng.uniform(0.1, 1.0)).astype(np.float32)
            k_max = int(rng.integers(1, M_ + 1))
            ref = np.flatnonzero((mask > 0) & ~busy)
            admitted, total = idx.select(AvailRow.from_mask(mask, bounds),
                                         busy, k_max)
            assert admitted == ref[:k_max].tolist(), (trial, step)
            assert total == ref.size, (trial, step)
            busy[admitted] = True
            idx.start_batch(admitted)
            done = np.flatnonzero(busy)
            fin = rng.choice(done, size=int(rng.integers(0, done.size + 1)),
                             replace=False)
            busy[fin] = False
            idx.finish_batch(fin.tolist())


def test_sparse_matches_dense_on_markov_fleets():
    """Cohort-indexed DES == the dense per-client reference scan on bursty
    Markov and shared-chain fleets — the availability kinds the sparse
    mask protocol encodes as 'not_ids'/'none' rows instead of dense
    masks."""
    for seed in range(3):
        pop = ClientPopulation(cohorts=(
            Cohort(name="a", n=5, delay=DelayModel(base=0.3, scale=0.3),
                   availability="markov", p_dropout=0.3, p_recover=0.4),
            Cohort(name="b", n=3, delay=DelayModel(base=2.0, scale=0.5),
                   availability="markov-shared", p_dropout=0.25,
                   p_recover=0.5),
            Cohort(name="c", n=4, delay=DelayModel(base=1.0, scale=0.2),
                   participation=0.6),
        ))
        sched = strag.make_schedule(seed, 6, population=pop, t_server=0.1,
                                    t_comm=0.05)
        for quorum, discount in ((0, 1.0), (4, 0.5)):
            dense = events.compile_timeline(sched, 14, quorum=quorum,
                                            discount=discount, tau=2)
            got = events.compile_sparse_timeline(
                sched, 14, quorum=quorum, discount=discount,
                tau=2).densify()
            for f in ("arrival_time", "client_id", "cohort_id",
                      "round_of_origin", "staleness", "commit_idx",
                      "start_mask", "apply_w", "staleness_m",
                      "commit_times", "durations", "quorum_wait",
                      "applied"):
                assert np.array_equal(getattr(dense, f),
                                      getattr(got, f)), (seed, quorum, f)


def test_stack_sparse_chunk_subset_matches_gather():
    """O(K) staging == the fleet-width gather bit for bit, including the
    pad-row convention: -1 pads clip to client 0 on both paths (their
    records land in the ring's dropped pad slot)."""
    Mf = 6

    def batch_fn(r):
        x = np.arange(Mf * 3, dtype=np.float32).reshape(Mf, 3) + 100.0 * r
        return {"x": x, "y": np.arange(Mf, dtype=np.int64) * (r + 1)}

    def subset_fn(r, ids):
        return {k: v[np.asarray(ids)] for k, v in batch_fn(r).items()}

    starts = np.array([[1, 4, -1], [0, 2, 5], [-1, -1, -1]], np.int64)
    a = engine._stack_sparse_chunk(batch_fn, 3, starts)
    b = engine._stack_sparse_chunk(batch_fn, 3, starts,
                                   subset_fn=subset_fn)
    for k in ("x", "y"):
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k
    assert np.array_equal(np.asarray(b["x"])[0, 2],
                          batch_fn(3)["x"][0])        # pad row == client 0
    # batch_put sees the stacked chunk last
    seen = []
    engine._stack_sparse_chunk(batch_fn, 3, starts, subset_fn=subset_fn,
                               batch_put=lambda t: seen.append(t) or t)
    assert np.array_equal(np.asarray(seen[0]["x"]), np.asarray(b["x"]))


def _subset_of(batch_fn):
    def f(r, ids):
        b = jax.tree.map(np.asarray, batch_fn(r))
        idx = np.asarray(ids)
        return jax.tree.map(lambda x: x[idx], b)
    return f


def test_subset_staging_end_to_end_and_resume(setup):
    """run_rounds(batch_subset_fn=...) == the gather path bit for bit on
    the full async sparse trajectory, rejected outside the sparse path,
    and exact through checkpoint resume."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1, lr_server=5e-3,
                    lr_client=1e-3, lr_global=1.0, population=pop,
                    quorum=3, staleness_discount=0.5, timeline="sparse")
    sub_fn = _subset_of(batch_fn)
    ref = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=ROUNDS, mode="async",
                            chunk_size=2)
    sub = engine.run_rounds("async_mu_splitfed", cfg, sfl, params, batch_fn,
                            sched, key, rounds=ROUNDS, mode="async",
                            chunk_size=2, batch_subset_fn=sub_fn)
    assert np.array_equal(ref.round_loss, sub.round_loss)
    assert maxdiff(ref.params, sub.params) == 0.0
    assert maxdiff(ref.state, sub.state) == 0.0
    with pytest.raises(ValueError, match="O\\(K\\) staging"):
        engine.run_rounds("async_mu_splitfed", cfg,
                          dataclasses.replace(sfl, timeline="dense"),
                          params, batch_fn, sched, key, rounds=2,
                          mode="async", batch_subset_fn=sub_fn)
    with pytest.raises(ValueError, match="batch_put"):
        engine.run_rounds("async_mu_splitfed", cfg,
                          dataclasses.replace(sfl, timeline="dense"),
                          params, batch_fn, sched, key, rounds=2,
                          mode="async", batch_put=lambda t: t)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        part1 = engine.run_rounds("async_mu_splitfed", cfg, sfl, params,
                                  batch_fn, sched, key, rounds=4,
                                  mode="async", chunk_size=2,
                                  checkpointer=ck, ckpt_every=2,
                                  batch_subset_fn=sub_fn)
        ck.wait()
        p2, s2, meta = engine.restore_run(ck, "async_mu_splitfed", cfg, sfl,
                                          params, batch_fn)
        part2 = engine.run_rounds("async_mu_splitfed", cfg, sfl, p2,
                                  batch_fn, sched, key, rounds=ROUNDS,
                                  start_round=meta["step"] + 1, state=s2,
                                  mode="async", chunk_size=2,
                                  batch_subset_fn=sub_fn)
    resumed = np.concatenate([part1.round_loss, part2.round_loss])
    assert np.array_equal(ref.round_loss, resumed)
    assert maxdiff(ref.params, part2.params) == 0.0


def test_sparse_adaptive_tau_matches_dense(setup):
    """The controller re-plans τ mid-run over BOTH backends: the sparse
    stream rebuilds from the re-planned version with the resized ring and
    must land the same trajectory and τ decisions as the dense path."""
    cfg, params, _, _, batch_fn, key = setup
    pop = tiered_pop(base_slow=1.0)
    sched = strag.make_schedule(0, ROUNDS, population=pop, t_server=0.1)
    base = SFLConfig(n_clients=M, tau=1, cut_units=1, lr_server=5e-3,
                     lr_client=1e-3, lr_global=1.0, population=pop,
                     quorum=3, staleness_discount=0.5)
    dn = engine.run_rounds("async_mu_splitfed", cfg, base, params, batch_fn,
                           sched, key, rounds=ROUNDS, mode="async",
                           chunk_size=2,
                           controller=engine.AdaptiveTau(tau_max=8))
    sp = engine.run_rounds("async_mu_splitfed", cfg,
                           dataclasses.replace(base, timeline="sparse"),
                           params, batch_fn, sched, key, rounds=ROUNDS,
                           mode="async", chunk_size=2,
                           controller=engine.AdaptiveTau(tau_max=8))
    assert np.array_equal(dn.tau_per_round, sp.tau_per_round)
    assert np.max(np.abs(dn.round_loss - sp.round_loss)) <= 1e-5
    assert np.array_equal(dn.round_times, sp.round_times)
