"""Per-kernel interpret-mode validation: shape/dtype sweeps against the
pure-jnp oracles in kernels/ref.py (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (flash_attention_op, rmsnorm_op, zo_update_leaf,
                               zo_update_tree)

# ---------------------------------------------------------------------------
# zo_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (128,), (33, 65), (4, 16, 100),
                                   (1024,), (2048, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zo_update_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = jax.random.normal(key, shape, dtype)
    got = zo_update_leaf(x, 123, 0.37)
    want = ref.zo_update_ref(x, 123, 0.37)
    tol = 1e-6 if dtype == jnp.float32 else 0.05
    assert got.dtype == x.dtype
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) <= tol


def test_zo_update_offset_consistency():
    """Splitting an array into two row-offset calls must equal one call —
    the counter stream is position-based, not call-based."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2048,), jnp.float32)
    whole = zo_update_leaf(x, 9, 1.0)
    a = zo_update_leaf(x[:1024], 9, 1.0, row_offset=0)
    b = zo_update_leaf(x[1024:], 9, 1.0, row_offset=1)
    assert float(jnp.max(jnp.abs(whole - jnp.concatenate([a, b])))) < 1e-6


def test_zo_update_tree_distinct_streams():
    params = {"a": jnp.zeros((512,)), "b": jnp.zeros((512,))}
    out = zo_update_tree(params, 5, 1.0)
    assert float(jnp.max(jnp.abs(out["a"] - out["b"]))) > 0.1


def test_counter_gauss_moments():
    u = ref.counter_gauss(jnp.uint32(3), jnp.arange(200_000, dtype=jnp.uint32))
    assert abs(float(u.mean())) < 0.02
    assert abs(float(u.std()) - 1.0) < 0.02
    # tail sanity: P(|u|>3) ~ 0.0027
    frac = float(jnp.mean(jnp.abs(u) > 3.0))
    assert 0.0005 < frac < 0.01


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (1, 512), (130, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(2), (shape[-1],),
                              jnp.float32)
    got = rmsnorm_op(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) <= 1e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,d,H,Hkv", [(128, 64, 4, 4), (128, 64, 4, 2),
                                       (256, 32, 2, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_matches_ref(S, d, H, Hkv, causal, window):
    key = jax.random.PRNGKey(3)
    B = 2
    q = jax.random.normal(key, (B, H, S, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, Hkv, S, d), jnp.float32)
    got = flash_attention_op(q, k, v, causal=causal, window=window,
                             bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (1, 2, 128, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 128, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 128, 64), dtype)
    got = flash_attention_op(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    assert got.dtype == dtype
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < 0.05
