"""Hypothesis property tests on the event-timeline invariants
(core/events.py): conservation of contributions, commit-time monotonicity,
weight normalization, and sparse == dense on random small fleets."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import events
from repro.core import straggler as strag
from repro.core.population import (AvailRow, ClientPopulation, Cohort,
                                   DelayModel)

SET = dict(max_examples=20, deadline=None)

# discounts whose staleness powers are dyadic: per-commit normalization is
# then a division of exactly representable sums, so dense (M zero-padded
# records) and sparse (K records) group-equivalently, bit for bit
DYADIC = st.sampled_from([1.0, 0.5, 0.25])

FLEET = st.fixed_dictionaries(dict(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(2, 12),
    V=st.integers(0, 20),
    quorum=st.integers(0, 12),
    discount=DYADIC,
    scale=st.floats(0.0, 3.0, allow_nan=False),
    part=st.floats(0.3, 1.0, allow_nan=False),
    t_server=st.floats(0.01, 1.0, allow_nan=False),
))


def _sched(p):
    return strag.make_schedule(p["seed"], 4, p["M"],
                               straggler_scale=p["scale"],
                               participation=p["part"],
                               t_server=p["t_server"], t_comm=0.05)


def _dense(p):
    return events.compile_timeline(_sched(p), p["V"],
                                   quorum=min(p["quorum"], p["M"]),
                                   discount=p["discount"], tau=2)


@settings(**SET)
@given(p=FLEET)
def test_every_start_commits_once_or_is_in_flight(p):
    """Conservation: each (version, client) start produces exactly one
    event — committed at exactly one commit_idx, or in flight (-1) at the
    horizon. Nothing is double-applied, nothing vanishes."""
    tl = _dense(p)
    starts = sorted(map(tuple, np.argwhere(tl.start_mask > 0)))
    evs = sorted(zip(tl.round_of_origin.tolist(), tl.client_id.tolist()))
    assert evs == starts
    committed = tl.commit_idx[tl.commit_idx >= 0]
    assert np.all(committed < max(p["V"], 1))


@settings(**SET)
@given(p=FLEET)
def test_commit_times_non_decreasing(p):
    tl = _dense(p)
    assert np.all(np.diff(tl.commit_times) >= 0)
    assert np.all(tl.durations >= 0)
    assert np.all(tl.quorum_wait >= 0)


@settings(**SET)
@given(p=FLEET)
def test_commit_weights_sum_to_one_or_zero(p):
    """Each commit's staleness-discounted weights are normalized: they sum
    to 1 when anything applied, exactly 0 when nothing did."""
    tl = _dense(p)
    sums = tl.apply_w.sum(axis=1)
    applied = tl.applied > 0
    assert np.allclose(sums[applied], 1.0, atol=1e-6)
    assert np.all(sums[~applied] == 0.0)


@settings(**SET)
@given(p=FLEET)
def test_sparse_equals_dense_on_random_fleets(p):
    """The heap DES at exact geometry reproduces the dense compiler
    field-for-field on arbitrary small fleets."""
    q = min(p["quorum"], p["M"])
    dense = events.compile_timeline(_sched(p), p["V"], quorum=q,
                                    discount=p["discount"], tau=2)
    got = events.compile_sparse_timeline(_sched(p), p["V"], quorum=q,
                                         discount=p["discount"],
                                         tau=2).densify()
    for f in ("arrival_time", "client_id", "round_of_origin", "staleness",
              "commit_idx", "start_mask", "apply_w", "staleness_m",
              "commit_times", "durations", "quorum_wait", "applied",
              "tau_per_version"):
        assert np.array_equal(getattr(dense, f), getattr(got, f)), f


COHORT = st.fixed_dictionaries(dict(
    n=st.integers(1, 6),
    base=st.floats(0.1, 3.0, allow_nan=False),
    scale=st.floats(0.0, 1.0, allow_nan=False),
    availability=st.sampled_from(["iid", "markov", "markov-shared"]),
    p_dropout=st.floats(0.0, 0.6, allow_nan=False),
    p_recover=st.floats(0.1, 1.0, allow_nan=False),
    part=st.floats(0.3, 1.0, allow_nan=False),
))

MARKOV_FLEET = st.fixed_dictionaries(dict(
    seed=st.integers(0, 2**31 - 1),
    cohorts=st.lists(COHORT, min_size=1, max_size=3),
    V=st.integers(0, 16),
    quorum=st.integers(0, 8),
    discount=DYADIC,
))


@settings(**SET)
@given(p=MARKOV_FLEET)
def test_cohort_index_equals_dense_scan_on_markov_fleets(p):
    """The cohort-indexed idle sets reproduce the dense compiler's
    per-client ``flatnonzero``-style reference scan field-for-field on
    random heterogeneous fleets with bursty Markov and shared-chain
    availability — the kinds the streaming mask protocol encodes as
    sparse 'ids'/'not_ids'/'none' rows."""
    pop = ClientPopulation(cohorts=tuple(
        Cohort(name=f"c{i}", n=c["n"],
               delay=DelayModel(base=c["base"], scale=c["scale"]),
               participation=c["part"], availability=c["availability"],
               p_dropout=c["p_dropout"], p_recover=c["p_recover"])
        for i, c in enumerate(p["cohorts"])))
    sched = strag.make_schedule(p["seed"], 4, population=pop,
                                t_server=0.2, t_comm=0.05)
    q = min(p["quorum"], pop.n_clients)
    dense = events.compile_timeline(sched, p["V"], quorum=q,
                                    discount=p["discount"], tau=2)
    got = events.compile_sparse_timeline(sched, p["V"], quorum=q,
                                         discount=p["discount"],
                                         tau=2).densify()
    for f in ("arrival_time", "client_id", "cohort_id", "round_of_origin",
              "staleness", "commit_idx", "start_mask", "apply_w",
              "staleness_m", "commit_times", "durations", "quorum_wait",
              "applied"):
        assert np.array_equal(getattr(dense, f), getattr(got, f)), f


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), n_steps=st.integers(1, 12))
def test_idle_index_select_is_flatnonzero(seed, n_steps):
    """Direct contract of _CohortIdleIndex.select: admitted ids ==
    ``flatnonzero((mask > 0) & ~busy)[:k_max]`` and the candidate count
    is exact, under arbitrary start/finish churn."""
    rng = np.random.default_rng(seed)
    M_ = int(rng.integers(2, 40))
    n_cuts = int(rng.integers(0, min(4, M_ - 1) + 1))
    cuts = (sorted(rng.choice(np.arange(1, M_), size=n_cuts,
                              replace=False).tolist()) if n_cuts else [])
    bounds = list(zip([0] + cuts, cuts + [M_]))
    idx = events._CohortIdleIndex(bounds)
    busy = np.zeros(M_, bool)
    for _ in range(n_steps):
        mask = (rng.random(M_) < rng.uniform(0.1, 1.0)).astype(np.float32)
        k_max = int(rng.integers(1, M_ + 1))
        ref = np.flatnonzero((mask > 0) & ~busy)
        admitted, total = idx.select(AvailRow.from_mask(mask, bounds),
                                     busy, k_max)
        assert admitted == ref[:k_max].tolist()
        assert total == ref.size
        busy[admitted] = True
        idx.start_batch(admitted)
        done = np.flatnonzero(busy)
        fin = rng.choice(done, size=int(rng.integers(0, done.size + 1)),
                         replace=False)
        busy[fin] = False
        idx.finish_batch(fin.tolist())


@settings(**SET)
@given(p=FLEET, k_max=st.integers(1, 6), cap_mult=st.integers(1, 4))
def test_bounded_ring_conserves_contributions(p, k_max, cap_mult):
    """Under forced truncation/eviction, the per-version counters still
    balance: starts and applies respect the k_max batch width, and every
    start is eventually applied, evicted, or in flight (the residual is
    bounded by the ring capacity)."""
    capacity = min(k_max * cap_mult, p["M"])
    stream = events.TimelineStream(_sched(p), p["V"],
                                   quorum=min(p["quorum"], p["M"]),
                                   discount=p["discount"], taus=2,
                                   k_max=k_max, capacity=capacity)
    rows = stream.take(p["V"])
    assert np.all(rows.started <= k_max)
    assert np.all(rows.applied <= k_max)
    in_flight = (rows.started.sum() - rows.applied.sum()
                 - rows.evicted.sum())
    assert 0 <= in_flight <= capacity
    assert np.all(rows.skipped >= 0)
    # padded row slots are inert by construction: zero weight, and the pad
    # slot index is the one the device scatter drops / gather clamps
    w = rows.apply_w
    assert np.all(w[rows.apply_client < 0] == 0.0)
    assert np.all(rows.start_slot[rows.start_client < 0] == capacity)


# ---------------------------------------------------------------------------
# fault-plan invariants (core/faults.py): conservation and liveness hold
# for ARBITRARY plans, not just the benchmark's curated rates
# ---------------------------------------------------------------------------

from repro.core.faults import (STALE_CORRUPT, STALE_CRASH,   # noqa: E402
                               STALE_LOST, FaultPlan)

FAULT_PLAN = st.builds(
    FaultPlan,
    crash=st.floats(0.0, 0.8, allow_nan=False),
    loss=st.floats(0.0, 0.8, allow_nan=False),
    dup=st.floats(0.0, 1.0, allow_nan=False),
    corrupt=st.floats(0.0, 0.8, allow_nan=False),
    backoff=st.floats(0.05, 1.0, allow_nan=False),
)

FAULT_FLEET = st.fixed_dictionaries(dict(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(2, 10),
    V=st.integers(0, 16),
    quorum=st.integers(1, 10),
    timeout=st.floats(0.2, 2.0, allow_nan=False),
    discount=DYADIC,
    scale=st.floats(0.0, 3.0, allow_nan=False),
    part=st.floats(0.3, 1.0, allow_nan=False),
    t_server=st.floats(0.01, 1.0, allow_nan=False),
    plan=FAULT_PLAN,
))


@settings(**SET)
@given(p=FAULT_FLEET)
def test_fault_conservation_and_liveness_under_random_plans(p):
    """For any FaultPlan with a quorum_timeout escape: every dispatch is
    accounted exactly once (delivered, or dropped with a reason code whose
    per-version counters balance), commit times stay finite and
    non-decreasing (liveness), and the sparse DES agrees with the dense
    compiler field-for-field, fault columns included."""
    tl = events.compile_timeline(_sched(p), p["V"],
                                 quorum=min(p["quorum"], p["M"]),
                                 discount=p["discount"], tau=2,
                                 faults=p["plan"],
                                 quorum_timeout=p["timeout"])
    for v in range(p["V"]):
        rows = tl.round_of_origin == v
        st_ = tl.staleness[rows]
        assert tl.started[v] == rows.sum()
        assert (st_ == STALE_CRASH).sum() == tl.crashed[v]
        assert (st_ == STALE_LOST).sum() == tl.lost[v]
        assert (st_ == STALE_CORRUPT).sum() == tl.corrupt[v]
        assert (st_ >= -1).sum() == tl.started[v] - tl.crashed[v] \
            - tl.lost[v] - tl.corrupt[v]
    dropped = tl.staleness < -1
    assert np.all(tl.commit_idx[dropped] == -1)
    assert np.all(np.isfinite(tl.commit_times))
    assert np.all(np.diff(tl.commit_times) >= 0)
    assert np.all(tl.durations >= 0)
    sums = tl.apply_w.sum(axis=1)
    applied = tl.applied > 0
    assert np.allclose(sums[applied], 1.0, atol=1e-6)
    assert np.all(sums[~applied] == 0.0)

    got = events.compile_sparse_timeline(
        _sched(p), p["V"], quorum=min(p["quorum"], p["M"]),
        discount=p["discount"], tau=2, faults=p["plan"],
        quorum_timeout=p["timeout"]).densify()
    import dataclasses
    for f in dataclasses.fields(events.Timeline):
        x, y = getattr(tl, f.name), getattr(got, f.name)
        assert (x is None) == (y is None), f.name
        if x is not None:
            assert np.array_equal(x, y), f.name
