"""Hypothesis property tests on the event-timeline invariants
(core/events.py): conservation of contributions, commit-time monotonicity,
weight normalization, and sparse == dense on random small fleets."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import events
from repro.core import straggler as strag

SET = dict(max_examples=20, deadline=None)

# discounts whose staleness powers are dyadic: per-commit normalization is
# then a division of exactly representable sums, so dense (M zero-padded
# records) and sparse (K records) group-equivalently, bit for bit
DYADIC = st.sampled_from([1.0, 0.5, 0.25])

FLEET = st.fixed_dictionaries(dict(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(2, 12),
    V=st.integers(0, 20),
    quorum=st.integers(0, 12),
    discount=DYADIC,
    scale=st.floats(0.0, 3.0, allow_nan=False),
    part=st.floats(0.3, 1.0, allow_nan=False),
    t_server=st.floats(0.01, 1.0, allow_nan=False),
))


def _sched(p):
    return strag.make_schedule(p["seed"], 4, p["M"],
                               straggler_scale=p["scale"],
                               participation=p["part"],
                               t_server=p["t_server"], t_comm=0.05)


def _dense(p):
    return events.compile_timeline(_sched(p), p["V"],
                                   quorum=min(p["quorum"], p["M"]),
                                   discount=p["discount"], tau=2)


@settings(**SET)
@given(p=FLEET)
def test_every_start_commits_once_or_is_in_flight(p):
    """Conservation: each (version, client) start produces exactly one
    event — committed at exactly one commit_idx, or in flight (-1) at the
    horizon. Nothing is double-applied, nothing vanishes."""
    tl = _dense(p)
    starts = sorted(map(tuple, np.argwhere(tl.start_mask > 0)))
    evs = sorted(zip(tl.round_of_origin.tolist(), tl.client_id.tolist()))
    assert evs == starts
    committed = tl.commit_idx[tl.commit_idx >= 0]
    assert np.all(committed < max(p["V"], 1))


@settings(**SET)
@given(p=FLEET)
def test_commit_times_non_decreasing(p):
    tl = _dense(p)
    assert np.all(np.diff(tl.commit_times) >= 0)
    assert np.all(tl.durations >= 0)
    assert np.all(tl.quorum_wait >= 0)


@settings(**SET)
@given(p=FLEET)
def test_commit_weights_sum_to_one_or_zero(p):
    """Each commit's staleness-discounted weights are normalized: they sum
    to 1 when anything applied, exactly 0 when nothing did."""
    tl = _dense(p)
    sums = tl.apply_w.sum(axis=1)
    applied = tl.applied > 0
    assert np.allclose(sums[applied], 1.0, atol=1e-6)
    assert np.all(sums[~applied] == 0.0)


@settings(**SET)
@given(p=FLEET)
def test_sparse_equals_dense_on_random_fleets(p):
    """The heap DES at exact geometry reproduces the dense compiler
    field-for-field on arbitrary small fleets."""
    q = min(p["quorum"], p["M"])
    dense = events.compile_timeline(_sched(p), p["V"], quorum=q,
                                    discount=p["discount"], tau=2)
    got = events.compile_sparse_timeline(_sched(p), p["V"], quorum=q,
                                         discount=p["discount"],
                                         tau=2).densify()
    for f in ("arrival_time", "client_id", "round_of_origin", "staleness",
              "commit_idx", "start_mask", "apply_w", "staleness_m",
              "commit_times", "durations", "quorum_wait", "applied",
              "tau_per_version"):
        assert np.array_equal(getattr(dense, f), getattr(got, f)), f


@settings(**SET)
@given(p=FLEET, k_max=st.integers(1, 6), cap_mult=st.integers(1, 4))
def test_bounded_ring_conserves_contributions(p, k_max, cap_mult):
    """Under forced truncation/eviction, the per-version counters still
    balance: starts and applies respect the k_max batch width, and every
    start is eventually applied, evicted, or in flight (the residual is
    bounded by the ring capacity)."""
    capacity = min(k_max * cap_mult, p["M"])
    stream = events.TimelineStream(_sched(p), p["V"],
                                   quorum=min(p["quorum"], p["M"]),
                                   discount=p["discount"], taus=2,
                                   k_max=k_max, capacity=capacity)
    rows = stream.take(p["V"])
    assert np.all(rows.started <= k_max)
    assert np.all(rows.applied <= k_max)
    in_flight = (rows.started.sum() - rows.applied.sum()
                 - rows.evicted.sum())
    assert 0 <= in_flight <= capacity
    assert np.all(rows.skipped >= 0)
    # padded row slots are inert by construction: zero weight, and the pad
    # slot index is the one the device scatter drops / gather clamps
    w = rows.apply_w
    assert np.all(w[rows.apply_client < 0] == 0.0)
    assert np.all(rows.start_slot[rows.start_client < 0] == capacity)
