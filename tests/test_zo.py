"""ZO engine: estimator statistics, seed replay exactness, sphere scaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import maxdiff
from repro.core import zo


def quad_loss(params):
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))


def test_spsa_estimates_gradient_direction():
    """E[g] -> ∇f_λ ≈ ∇f for a quadratic; with many perturbations the
    average estimate must correlate strongly with the true gradient."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64,)), "b": jnp.ones((8,))}
    true_g = jax.grad(quad_loss)(params)
    g = zo.zo_gradient(quad_loss, params, key, eps=1e-4, n_perturbations=256)
    tg = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(true_g)])
    eg = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(g)])
    cos = jnp.dot(tg, eg) / (jnp.linalg.norm(tg) * jnp.linalg.norm(eg))
    assert float(cos) > 0.5, float(cos)


def test_seed_replay_exactness():
    """Replaying (key, coeff) records must reproduce the direct update
    bit-exactly — the compressed-aggregation wire format guarantee."""
    key = jax.random.PRNGKey(1)
    params = {"a": jax.random.normal(key, (33, 17)),
              "b": {"c": jnp.zeros((5,))}}
    new_p, _, (keys, coeffs) = zo.spsa_step(quad_loss, params, key,
                                            eps=1e-3, lr=0.1,
                                            n_perturbations=3)
    replayed = zo.replay_updates(params, keys, coeffs)
    assert maxdiff(new_p, replayed) == 0.0


def test_perturb_antisymmetry():
    key = jax.random.PRNGKey(2)
    params = {"w": jnp.ones((100,))}
    up = zo.perturb(params, key, +0.5)
    dn = zo.perturb(params, key, -0.5)
    mid = jax.tree.map(lambda a, b: (a + b) / 2, up, dn)
    assert maxdiff(mid, params) < 1e-6


def test_sphere_distribution_norm():
    """Sphere-mode noise must satisfy ‖u‖ = √d globally across leaves."""
    key = jax.random.PRNGKey(3)
    params = {"a": jnp.zeros((50, 20)), "b": jnp.zeros((123,))}
    u = zo.tree_noise(key, params, dist="sphere")
    d = sum(x.size for x in jax.tree.leaves(u))
    norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(u))))
    assert abs(norm - np.sqrt(d)) < 1e-2


def test_noise_deterministic_and_leaf_independent():
    key = jax.random.PRNGKey(4)
    params = {"a": jnp.zeros((16,)), "b": jnp.zeros((16,))}
    u1 = zo.tree_noise(key, params)
    u2 = zo.tree_noise(key, params)
    assert maxdiff(u1, u2) == 0.0
    assert float(jnp.max(jnp.abs(u1["a"] - u1["b"]))) > 0  # distinct streams


def test_spsa_step_descends_quadratic():
    key = jax.random.PRNGKey(5)
    params = {"w": jax.random.normal(key, (32,)) * 3}
    p = params
    for i in range(50):
        p, _, _ = zo.spsa_step(quad_loss, p, jax.random.fold_in(key, i),
                               eps=1e-3, lr=5e-3, n_perturbations=4)
    assert float(quad_loss(p)) < float(quad_loss(params)) * 0.7
