"""Fused batched seed-replay engine (perf-ladder v4): equivalence of the
one-pass replay against the sequential scan path at every level —
kernel (interpret mode), pytree engine, and full MU-SplitFed / GAS rounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, maxdiff, tiny_lm_cfg
from repro.configs import SFLConfig
from repro.core import zo
from repro.core.baselines import gas_init_state, gas_round
from repro.core.splitfed import mu_splitfed_round
from repro.kernels import ref
from repro.kernels.ops import zo_replay_leaf
from repro.kernels.zo_update import LANE, zo_replay_flat, zo_update_flat
from repro.models import init_params, untie_params

NS = [1, 8, 64]


def _records(n, salt=0):
    rng = np.random.default_rng(1234 + salt)
    seeds = jnp.asarray(rng.integers(0, 2 ** 32, size=n, dtype=np.uint32))
    coeffs = jnp.asarray((rng.normal(size=n) * 0.1).astype(np.float32))
    return seeds, coeffs


# ---------------------------------------------------------------------------
# kernel level: zo_replay_flat == N × zo_update_flat == ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", NS)
def test_zo_replay_flat_equals_sequential_updates(n):
    """One batched kernel call must equal N single-record kernel calls
    (up to f32 summation order)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, LANE), jnp.float32)
    seeds, coeffs = _records(n)
    fused = zo_replay_flat(x, seeds, coeffs, interpret=True)
    seq = x
    for i in range(n):
        seq = zo_update_flat(seq, seeds[i], coeffs[i], interpret=True)
    assert float(jnp.max(jnp.abs(fused - seq))) <= 1e-5


@pytest.mark.parametrize("n", NS)
def test_zo_replay_flat_equals_ref(n):
    x = jax.random.normal(jax.random.PRNGKey(1), (4, LANE), jnp.float32)
    seeds, coeffs = _records(n, salt=1)
    fused = zo_replay_flat(x, seeds, coeffs, interpret=True)
    want = ref.zo_replay_ref(x, seeds, coeffs)
    assert float(jnp.max(jnp.abs(fused - want))) <= 1e-5


def test_zo_replay_leaf_pallas_equals_ref_padded():
    """Odd-shaped leaf exercises the pad/unpad path of both backends."""
    x = jax.random.normal(jax.random.PRNGKey(2), (37, 11), jnp.float32)
    seeds, coeffs = _records(8, salt=2)
    a = zo_replay_leaf(x, seeds, coeffs, impl="pallas", interpret=True)
    b = zo_replay_leaf(x, seeds, coeffs, impl="ref")
    assert a.shape == x.shape
    assert float(jnp.max(jnp.abs(a - b))) <= 1e-5


def test_zo_replay_ref_windowed_scan_matches_blockwise():
    """Above the window width the ref switches to a lax.scan of 8-record
    unrolled windows (bounded XLA temp footprint) — same stream, same
    sequential record order."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, LANE), jnp.float32)
    seeds, coeffs = _records(16 * ref._REPLAY_WINDOW + 3, salt=3)
    big = ref.zo_replay_ref(x, seeds, coeffs)
    acc = x
    for i in range(0, seeds.shape[0], 16):
        acc = ref.zo_replay_ref(acc, seeds[i:i + 16], coeffs[i:i + 16])
    assert float(jnp.max(jnp.abs(big - acc))) <= 1e-4


def test_zo_replay_ref_window_boundary_padding():
    """The windowed scan (n > W, zero-coeff padded to a whole window) must
    reproduce the sequential-order accumulation of the same records —
    padding contributes exactly zero, only compiler-level fma fusion may
    differ."""
    x = jax.random.normal(jax.random.PRNGKey(12), (2, LANE), jnp.float32)
    n = ref._REPLAY_WINDOW + 3            # ragged: exercises the padding
    seeds, coeffs = _records(n, salt=12)
    windowed = ref.zo_replay_ref(x, seeds, coeffs)
    acc = jnp.zeros_like(x)
    hi = jnp.zeros((2, LANE), jnp.uint32) + jnp.arange(2, dtype=jnp.uint32)[:, None]
    lo = jnp.broadcast_to(jnp.arange(LANE, dtype=jnp.uint32)[None, :], (2, LANE))
    for i in range(n):
        acc = acc + coeffs[i] * ref.counter_gauss2(seeds[i], hi, lo)
    assert float(jnp.max(jnp.abs(windowed - (x + acc)))) <= 1e-6


def test_zo_replay_leaf_chunks_past_smem_bound():
    """N past the kernel's SMEM record bound must be split at the ops
    layer into multiple fused sweeps, not fail at lowering — forced here
    with a tiny bound so 13 records take 4 kernel calls."""
    x = jax.random.normal(jax.random.PRNGKey(13), (37, 11), jnp.float32)
    seeds, coeffs = _records(13, salt=13)
    chunked = zo_replay_leaf(x, seeds, coeffs, impl="pallas",
                             interpret=True, max_records=4)
    want = zo_replay_leaf(x, seeds, coeffs, impl="ref")
    assert float(jnp.max(jnp.abs(chunked - want))) <= 1e-5


# ---------------------------------------------------------------------------
# engine level: fused_replay_updates == replay_updates (counter dist)
# ---------------------------------------------------------------------------

def _tree(key):
    ka, kb, kc = jax.random.split(key, 3)
    return {"a": jax.random.normal(ka, (33, 17), jnp.float32),
            "b": {"c": jax.random.normal(kb, (5,), jnp.float32),
                  "d": jax.random.normal(kc, (3, 4, 5), jnp.float32)}}


@pytest.mark.parametrize("n", NS)
def test_fused_replay_updates_matches_scan(n):
    params = _tree(jax.random.PRNGKey(4))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(5), i)
                    )(jnp.arange(n))
    coeffs = jnp.asarray(
        (np.random.default_rng(n).normal(size=n) * 0.05).astype(np.float32))
    fused = zo.fused_replay_updates(params, keys, coeffs, dist="counter")
    scan = zo.replay_updates(params, keys, coeffs, dist="counter")
    assert maxdiff(fused, scan) <= 1e-5


def test_fused_replay_gaussian_falls_back_to_scan():
    """Threefry dists are not counter-replayable: auto must produce the
    scan result bit-for-bit."""
    params = _tree(jax.random.PRNGKey(6))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(7), i)
                    )(jnp.arange(4))
    coeffs = jnp.full((4,), 0.01, jnp.float32)
    fused = zo.fused_replay_updates(params, keys, coeffs, dist="gaussian")
    scan = zo.replay_updates(params, keys, coeffs, dist="gaussian")
    assert maxdiff(fused, scan) == 0.0


def test_fused_impl_requires_counter():
    params = {"w": jnp.zeros((8,))}
    keys = jax.random.PRNGKey(0)[None]
    with pytest.raises(ValueError):
        zo.fused_replay_updates(params, keys, jnp.ones((1,)),
                                dist="gaussian", impl="fused")


def test_zo_update_tree_matches_engine_stream():
    """ops.zo_update_tree now draws the engine's per-leaf salted stream:
    replaying an engine record through it must be bit-identical to
    zo.apply_update(dist='counter')."""
    from repro.kernels.ops import zo_update_tree
    params = _tree(jax.random.PRNGKey(10))
    key = jax.random.PRNGKey(11)
    engine = zo.apply_update(params, key, 0.25, dist="counter")
    kernel = zo_update_tree(params, zo.record_seeds(key), -0.25)
    assert maxdiff(engine, kernel) == 0.0


def test_spsa_step_records_replay_through_fused_path():
    """spsa_step's returned records replayed via the fused path must land on
    the exact same params spsa_step itself produced (both go through
    fused_replay_updates with dist='counter')."""
    loss = lambda p: sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))
    params = _tree(jax.random.PRNGKey(8))
    new_p, _, (keys, coeffs) = zo.spsa_step(loss, params,
                                            jax.random.PRNGKey(9),
                                            1e-3, 0.1, 3, dist="counter")
    replayed = zo.fused_replay_updates(params, keys, coeffs, dist="counter")
    assert maxdiff(new_p, replayed) == 0.0


# ---------------------------------------------------------------------------
# round level: seed_replay aggregation, fused vs scan
# ---------------------------------------------------------------------------

M = 2


@pytest.fixture(scope="module")
def round_setup():
    cfg = tiny_lm_cfg(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = untie_params(cfg, init_params(cfg, key))
    batches = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16, M=M)
    sfl = SFLConfig(n_clients=M, tau=2, cut_units=1,
                    perturbation_dist="counter")
    return cfg, params, batches, sfl


@pytest.mark.parametrize("client_mode", ["parallel", "sequential"])
def test_round_seed_replay_fused_matches_scan(round_setup, client_mode):
    """mu_splitfed_round(aggregation='seed_replay'): the one-pass fused
    replay must match the N-step scan replay (f32, summation order only)."""
    cfg, params, batches, sfl = round_setup
    mask = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(7)
    p_f, m_f = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                                 client_mode=client_mode,
                                 aggregation="seed_replay", replay="fused")
    p_s, m_s = mu_splitfed_round(cfg, sfl, params, batches, mask, rk,
                                 client_mode=client_mode,
                                 aggregation="seed_replay", replay="scan")
    assert maxdiff(p_f, p_s) <= 1e-5
    assert jnp.allclose(m_f.loss, m_s.loss, atol=1e-6)
    assert maxdiff(p_f, params) > 0           # and it actually trained


def test_gas_seed_replay_matches_dense(round_setup):
    """GAS: replica-mean aggregation and record replay are the same update
    (sp_new − xs is exactly −Σ cᵢuᵢ), so the two must agree in f32."""
    cfg, params, batches, sfl = round_setup
    state = gas_init_state(cfg, sfl, params, batches)
    fresh = jnp.ones((M,), jnp.float32)
    rk = jax.random.PRNGKey(3)
    p_d, _, _ = gas_round(cfg, sfl, params, state, batches, fresh, rk,
                          aggregation="dense")
    p_r, _, _ = gas_round(cfg, sfl, params, state, batches, fresh, rk,
                          aggregation="seed_replay")
    assert maxdiff(p_d, p_r) <= 1e-5


def test_gas_rejects_unknown_aggregation(round_setup):
    cfg, params, batches, sfl = round_setup
    state = gas_init_state(cfg, sfl, params, batches)
    with pytest.raises(ValueError, match="aggregation"):
        gas_round(cfg, sfl, params, state, batches,
                  jnp.ones((M,), jnp.float32), jax.random.PRNGKey(0),
                  aggregation="bogus")
